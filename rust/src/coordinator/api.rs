//! Public container types and operators — the user-facing DSL.
//!
//! Mirrors the ArBB C++ API used in the paper's listings:
//!
//! | paper (ArBB C++)                  | here                          |
//! |-----------------------------------|-------------------------------|
//! | `dense<f64,2> A(n,n); bind(A,..)` | `ctx.bind2(&a, n, n)`         |
//! | `a.row(i)`, `b.col(j)`            | `a.row(i)`, `b.col(j)`        |
//! | `add_reduce(v)`                   | `v.add_reduce()`              |
//! | `add_reduce(d, 0)`                | `d.add_reduce_rows()`         |
//! | `repeat_row(v, n)`                | `v.repeat_row(n)`             |
//! | `repeat_col(v, n)`                | `v.repeat_col(n)`             |
//! | `section(v, s, l)` / strided      | `v.section(s, l)` / `_strided`|
//! | `cat(a, b)`                       | `a.cat(&b)`                   |
//! | `replace_col(c, i, v)`            | `c.replace_col(i, &v)`        |
//! | `map(f)(out, ...)`                | `ctx.map(...)`                |
//! | `_for` / `_while` (eager)         | rust `for` / `while` + `Scal::value()` |
//! | `arbb::call(closure)`             | [`super::program::ProgramBuilder`] → [`super::program::Program`] |
//! | `_for` (captured, trip at capture)| [`super::program::ProgramBuilder::repeat`] / [`ProgramBuilder::for_each`](super::program::ProgramBuilder::for_each) |
//! | JIT vectorization (SSE/AVX per ISA) | [`super::engine::backend`] dispatch: scalar reference / AVX2, detected at runtime, bit-identical by contract |
//! | perf instrumentation (VTune timelines in the paper's figures) | [`crate::obs`]: metrics registry + request trace spans ([`crate::obs::TraceRing`]) + per-opcode tape profiles ([`crate::obs::profile`]) |
//! | C++ exceptions out of `arbb::call` (§2: errors surface at the call site) | typed per-request errors: [`crate::Error`] from eager forces, [`crate::serve::ServeError`] from serving (deadline / panic / quarantine containment), faults injectable via [`crate::obs::faults`] |
//! | TBB-backed runtime scheduler, thread/core affinity (§2: many-core scaling without user threading code) | [`crate::serve`] sharded dispatcher: plan-affine routing to per-shard queues, idle-shard work stealing, per-shard interned pool slices, cost-aware batch formation ([`crate::serve::ServeConfig::shards`]) |
//! | external measurement harness (§3: the paper's OpenMP/MKL comparisons ran under wall-clock timers and VTune, outside the runtime) | the live observability plane: in-process HTTP scrape endpoints ([`crate::obs::HttpServer`] — `/metrics`, `/healthz`, `/readyz`, `/debug/trace`, `/debug/flight`), per-kernel SLO burn-rate tracking ([`crate::obs::SloTracker`]) and an anomaly-triggered flight recorder ([`crate::obs::FlightRecorder`]), so the latency decompositions the paper measured from outside are served continuously from inside ([`crate::serve::ObsConfig::listen_addr`]) |
//! | capture-time auto-optimisation (§2: a closure's first `arbb::call` runs the JIT's analysis + code generation once; later calls reuse the result) | the cost-based planner: startup calibration ([`super::engine::cost::CostModel`]), per-`(kernel, shape, backend)` exploration of alternative lowerings scored + probed at capture ([`super::passes::explore`]), winners memoized into the serve plan cache with runtime drift feedback and hot swap, persisted across restarts ([`crate::runtime::PlanStore`], [`crate::serve::ServeConfig::plan_store`]) |
//!
//! ArBB's `_for`/`_while` describe *serial* control flow whose body is
//! captured. This reproduction offers both cost models. On the eager
//! path plain rust loops play that role — each iteration extends the
//! pending DAG, and data-dependent conditions (`_while (r2 > stop)`)
//! force a sync exactly like ArBB's dynamic-data loops do; the
//! per-iteration dispatch cost the paper's CG results expose (§3.4) is
//! reproduced faithfully. The [`super::program`] subsystem is the
//! `arbb::call()` model: a whole multi-step computation — `_for` loops
//! with capture-resolved trip counts included — is captured once into a
//! replayable [`super::program::Program`] with a double-buffered buffer
//! plan, which is what the paper's capture-once/call-many cost claims
//! (§4) actually measure.


use std::sync::Arc;

use super::map::{Elemental, MapFn};
use super::node::{Data, Node, NodeRef, Op};
use super::ops::{BinOp, RedOp, UnOp};
use super::passes::constfold;
use super::shape::{DType, Shape};
use super::Context;

/// 1-D dense container of `f64` (the paper's `dense<f64>`).
#[derive(Clone)]
pub struct Vec1 {
    pub(crate) ctx: Context,
    pub(crate) node: NodeRef,
}

/// 2-D dense container of `f64`, row-major (the paper's `dense<f64,2>`).
#[derive(Clone)]
pub struct Mat2 {
    pub(crate) ctx: Context,
    pub(crate) node: NodeRef,
}

/// Scalar value living in "ArBB space" (result of a full reduction, loop
/// counters, `alpha`/`beta` of the CG solver, …).
#[derive(Clone)]
pub struct Scal {
    pub(crate) ctx: Context,
    pub(crate) node: NodeRef,
}

/// 1-D dense container of `i64` (the paper's `dense<i64>`, used for the
/// CSR `indx`/`rowp` arrays). Index containers are sources only: they are
/// captured by `map()` and `gather()`.
#[derive(Clone)]
pub struct VecI64 {
    pub(crate) ctx: Context,
    pub(crate) node: NodeRef,
}

/// Split-complex vector (re/im planes) for the FFT kernels. ArBB stores
/// `std::complex` containers; a structure-of-arrays split is the
/// data-parallel equivalent and fuses better.
#[derive(Clone)]
pub struct CplxV {
    pub re: Vec1,
    pub im: Vec1,
}

// ---------------------------------------------------------------------
// constructors
// ---------------------------------------------------------------------

impl Context {
    /// Bind a host slice into a 1-D container (copies, like ArBB `bind`).
    pub fn bind1(&self, host: &[f64]) -> Vec1 {
        let data = Data::F64(Arc::new(host.to_vec()));
        Vec1 { ctx: self.clone(), node: Node::new_source(Shape::D1(host.len()), data) }
    }

    /// Bind a host slice as a `rows x cols` row-major matrix.
    pub fn bind2(&self, host: &[f64], rows: usize, cols: usize) -> Mat2 {
        assert_eq!(host.len(), rows * cols, "bind2: host length != rows*cols");
        let data = Data::F64(Arc::new(host.to_vec()));
        Mat2 { ctx: self.clone(), node: Node::new_source(Shape::D2 { rows, cols }, data) }
    }

    /// Bind an i64 index container.
    pub fn bind_i64(&self, host: &[i64]) -> VecI64 {
        let data = Data::I64(Arc::new(host.to_vec()));
        VecI64 { ctx: self.clone(), node: Node::new_source(Shape::D1(host.len()), data) }
    }

    /// Zero-filled vector.
    pub fn zeros1(&self, n: usize) -> Vec1 {
        self.fill1(n, 0.0)
    }

    /// Constant-filled vector.
    pub fn fill1(&self, n: usize, v: f64) -> Vec1 {
        let data = Data::F64(Arc::new(vec![v; n]));
        Vec1 { ctx: self.clone(), node: Node::new_source(Shape::D1(n), data) }
    }

    /// Zero-filled matrix.
    pub fn zeros2(&self, rows: usize, cols: usize) -> Mat2 {
        let data = Data::F64(Arc::new(vec![0.0; rows * cols]));
        Mat2 { ctx: self.clone(), node: Node::new_source(Shape::D2 { rows, cols }, data) }
    }

    /// `0, 1, …, n-1`.
    pub fn iota(&self, n: usize) -> Vec1 {
        let data = Data::F64(Arc::new((0..n).map(|x| x as f64).collect()));
        Vec1 { ctx: self.clone(), node: Node::new_source(Shape::D1(n), data) }
    }

    /// Scalar constant in ArBB space.
    pub fn scalar(&self, v: f64) -> Scal {
        Scal { ctx: self.clone(), node: Node::new(Op::ConstF64(v), Shape::Scalar, DType::F64) }
    }

    /// Complex vector from interleaved host data `[re0, im0, re1, im1, …]`.
    pub fn bind_cplx_interleaved(&self, host: &[f64]) -> CplxV {
        assert!(host.len() % 2 == 0);
        let re: Vec<f64> = host.iter().step_by(2).copied().collect();
        let im: Vec<f64> = host.iter().skip(1).step_by(2).copied().collect();
        CplxV { re: self.bind1(&re), im: self.bind1(&im) }
    }

    /// ArBB `map()`: apply elemental `f` across `len` output elements.
    ///
    /// `captures` are resolved to slices positionally, split by dtype:
    /// inside `f`, `args.f(k)` is the k-th f64 capture, `args.i(k)` the
    /// k-th i64 capture.
    ///
    /// `flops_per_elem` / `bytes_per_elem` are cost hints for the scaling
    /// simulator (irregular kernels pass averages).
    pub fn map(
        &self,
        len: usize,
        captures: MapCaptures<'_>,
        f: Arc<Elemental>,
        flops_per_elem: f64,
        bytes_per_elem: f64,
        label: &'static str,
    ) -> Vec1 {
        let nodes: Vec<NodeRef> = captures.nodes;
        let mf = MapFn { captures: nodes, f, flops_per_elem, bytes_per_elem, label };
        Vec1 { ctx: self.clone(), node: Node::new(Op::Map(mf), Shape::D1(len), DType::F64) }
    }
}

/// Ordered capture list for [`Context::map`]. f64 and i64 captures keep
/// independent positional indices (matching [`super::map::MapArgs`]).
#[derive(Default)]
pub struct MapCaptures<'a> {
    nodes: Vec<NodeRef>,
    _marker: std::marker::PhantomData<&'a ()>,
}

impl<'a> MapCaptures<'a> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn f64(mut self, v: &'a Vec1) -> Self {
        self.nodes.push(v.node.clone());
        self
    }

    pub fn i64(mut self, v: &'a VecI64) -> Self {
        self.nodes.push(v.node.clone());
        self
    }
}

// ---------------------------------------------------------------------
// shared helpers
// ---------------------------------------------------------------------

fn bin_any(ctx: &Context, op: BinOp, l: &NodeRef, r: &NodeRef, shape: Shape) -> NodeRef {
    if let Some(folded) = constfold::fold_bin(op, l, r) {
        return folded;
    }
    if let Some(kept) = constfold::identity_elide(op, l, r) {
        return kept;
    }
    let _ = ctx;
    Node::new(Op::Bin(op, l.clone(), r.clone()), shape, DType::F64)
}

fn ew_shape(l: &NodeRef, r: &NodeRef) -> Shape {
    match (l.shape, r.shape) {
        (Shape::Scalar, s) => s,
        (s, Shape::Scalar) => s,
        (a, b) => {
            assert_eq!(a, b, "element-wise operands must have equal shape");
            a
        }
    }
}

// ---------------------------------------------------------------------
// Vec1
// ---------------------------------------------------------------------

impl Vec1 {
    pub fn len(&self) -> usize {
        self.node.shape.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn ew(&self, op: BinOp, rhs: &NodeRef) -> Vec1 {
        let shape = ew_shape(&self.node, rhs);
        Vec1 { ctx: self.ctx.clone(), node: bin_any(&self.ctx, op, &self.node, rhs, shape) }
    }

    fn un(&self, op: UnOp) -> Vec1 {
        Vec1 {
            ctx: self.ctx.clone(),
            node: Node::new(Op::Un(op, self.node.clone()), self.node.shape, DType::F64),
        }
    }

    /// Multiply by a host scalar.
    pub fn scale(&self, s: f64) -> Vec1 {
        let c = Node::new(Op::ConstF64(s), Shape::Scalar, DType::F64);
        self.ew(BinOp::Mul, &c)
    }

    /// Add a host scalar.
    pub fn offset(&self, s: f64) -> Vec1 {
        let c = Node::new(Op::ConstF64(s), Shape::Scalar, DType::F64);
        self.ew(BinOp::Add, &c)
    }

    pub fn sqrt(&self) -> Vec1 {
        self.un(UnOp::Sqrt)
    }

    pub fn abs(&self) -> Vec1 {
        self.un(UnOp::Abs)
    }

    pub fn neg(&self) -> Vec1 {
        self.un(UnOp::Neg)
    }

    pub fn exp(&self) -> Vec1 {
        self.un(UnOp::Exp)
    }

    pub fn min_ew(&self, other: &Vec1) -> Vec1 {
        self.ew(BinOp::Min, &other.node)
    }

    pub fn max_ew(&self, other: &Vec1) -> Vec1 {
        self.ew(BinOp::Max, &other.node)
    }

    /// `section(v, start, len)` — contiguous slice (virtual).
    pub fn section(&self, start: usize, len: usize) -> Vec1 {
        self.section_strided(start, len, 1)
    }

    /// `section(v, start, len, stride)` — strided slice (virtual). The
    /// FFT's even/odd splits use stride 2.
    pub fn section_strided(&self, start: usize, len: usize, stride: usize) -> Vec1 {
        assert!(len == 0 || start + (len - 1) * stride < self.len(), "section out of range");
        Vec1 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::Section { v: self.node.clone(), start, len, stride },
                Shape::D1(len),
                DType::F64,
            ),
        }
    }

    /// Cyclic tile: `repeat(v, times)` (virtual).
    pub fn repeat(&self, times: usize) -> Vec1 {
        Vec1 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::Repeat { v: self.node.clone(), times },
                Shape::D1(self.len() * times),
                DType::F64,
            ),
        }
    }

    /// Matrix whose every row is `self` (virtual): `t(m,k) = v(k)`.
    pub fn repeat_row(&self, rows: usize) -> Mat2 {
        Mat2 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::RepeatRow { v: self.node.clone(), rows },
                Shape::D2 { rows, cols: self.len() },
                DType::F64,
            ),
        }
    }

    /// Matrix whose every column is `self` (virtual): `t(m,k) = v(m)`.
    pub fn repeat_col(&self, cols: usize) -> Mat2 {
        Mat2 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::RepeatCol { v: self.node.clone(), cols },
                Shape::D2 { rows: self.len(), cols },
                DType::F64,
            ),
        }
    }

    /// Concatenation (materialising — the FFT's `cat(up, down)`).
    pub fn cat(&self, other: &Vec1) -> Vec1 {
        Vec1 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::Cat(self.node.clone(), other.node.clone()),
                Shape::D1(self.len() + other.len()),
                DType::F64,
            ),
        }
    }

    /// Gather: `out[k] = self[idx[k]]`.
    pub fn gather(&self, idx: &VecI64) -> Vec1 {
        Vec1 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::Gather { src: self.node.clone(), idx: idx.node.clone() },
                Shape::D1(idx.node.shape.len()),
                DType::F64,
            ),
        }
    }

    /// Scatter: `out[idx[k]] = self[k]` into a zero-initialised vector of
    /// length `len` (duplicate indices: the last write wins).
    pub fn scatter(&self, idx: &VecI64, len: usize) -> Vec1 {
        assert_eq!(idx.len(), self.len(), "scatter: index container length mismatch");
        Vec1 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::Scatter { src: self.node.clone(), idx: idx.node.clone(), len },
                Shape::D1(len),
                DType::F64,
            ),
        }
    }

    /// Segmented reduction with CSR row-pointer semantics:
    /// `out[r] = red over self[segp[r] .. segp[r+1]]`, with `segp` holding
    /// `nrows + 1` monotone offsets. Empty segments emit the reduction
    /// identity. Combined with [`Vec1::gather`] this expresses the §3.2
    /// spmv entirely in first-class ops:
    /// `(vals * x.gather(indx)).segmented_sum(rowp)`.
    pub fn segmented_reduce(&self, red: RedOp, segp: &VecI64) -> Vec1 {
        self.seg_reduce_inner(red, segp, false)
    }

    /// `segmented_reduce(Sum, segp)` — the spmv row sum.
    pub fn segmented_sum(&self, segp: &VecI64) -> Vec1 {
        self.seg_reduce_inner(RedOp::Sum, segp, false)
    }

    /// Contiguity-aware segmented sum (the paper's `arbb_spmv2`): asks
    /// the segmented executor to detect runs of consecutive columns in
    /// the fused gather's index table and stream them without the
    /// per-element gather. Bit-identical to [`Vec1::segmented_sum`].
    pub fn segmented_sum_runs(&self, segp: &VecI64) -> Vec1 {
        self.seg_reduce_inner(RedOp::Sum, segp, true)
    }

    fn seg_reduce_inner(&self, red: RedOp, segp: &VecI64, runs_hint: bool) -> Vec1 {
        assert!(!segp.is_empty(), "segmented_reduce: segp must hold nrows+1 offsets");
        let rows = segp.len() - 1;
        Vec1 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::SegmentedReduce {
                    red,
                    v: self.node.clone(),
                    segp: segp.node.clone(),
                    runs_hint,
                },
                Shape::D1(rows),
                DType::F64,
            ),
        }
    }

    /// Full sum reduction → scalar (the paper's `add_reduce(v)`).
    pub fn add_reduce(&self) -> Scal {
        Scal {
            ctx: self.ctx.clone(),
            node: Node::new(Op::ReduceAll(RedOp::Sum, self.node.clone()), Shape::Scalar, DType::F64),
        }
    }

    pub fn max_reduce(&self) -> Scal {
        Scal {
            ctx: self.ctx.clone(),
            node: Node::new(Op::ReduceAll(RedOp::Max, self.node.clone()), Shape::Scalar, DType::F64),
        }
    }

    pub fn min_reduce(&self) -> Scal {
        Scal {
            ctx: self.ctx.clone(),
            node: Node::new(Op::ReduceAll(RedOp::Min, self.node.clone()), Shape::Scalar, DType::F64),
        }
    }

    /// Dot product `Σ self·other` (fuses into a single pass).
    pub fn dot(&self, other: &Vec1) -> Scal {
        (self * other).add_reduce()
    }

    /// Force evaluation and copy out (the paper's `read_only_range`).
    pub fn to_vec(&self) -> Vec<f64> {
        self.ctx.force(&self.node);
        self.node.data().expect("forced").as_f64().as_ref().clone()
    }

    /// Force evaluation and copy into a host buffer.
    pub fn read_to(&self, out: &mut [f64]) {
        self.ctx.force(&self.node);
        let d = self.node.data().expect("forced");
        out.copy_from_slice(d.as_f64());
    }

    /// Force evaluation without reading (ArBB sync).
    pub fn eval(&self) {
        self.ctx.force(&self.node);
    }
}

macro_rules! impl_vec_binop {
    ($trait:ident, $method:ident, $op:expr, $lhs:ty, $rhs:ty) => {
        impl std::ops::$trait<$rhs> for $lhs {
            type Output = Vec1;
            fn $method(self, rhs: $rhs) -> Vec1 {
                self.ew($op, &rhs.node)
            }
        }
    };
}

impl_vec_binop!(Add, add, BinOp::Add, &Vec1, &Vec1);
impl_vec_binop!(Sub, sub, BinOp::Sub, &Vec1, &Vec1);
impl_vec_binop!(Mul, mul, BinOp::Mul, &Vec1, &Vec1);
impl_vec_binop!(Div, div, BinOp::Div, &Vec1, &Vec1);
impl_vec_binop!(Add, add, BinOp::Add, &Vec1, &Scal);
impl_vec_binop!(Sub, sub, BinOp::Sub, &Vec1, &Scal);
impl_vec_binop!(Mul, mul, BinOp::Mul, &Vec1, &Scal);
impl_vec_binop!(Div, div, BinOp::Div, &Vec1, &Scal);

impl std::ops::Add<&Vec1> for Vec1 {
    type Output = Vec1;
    fn add(self, rhs: &Vec1) -> Vec1 {
        (&self).add(rhs)
    }
}
impl std::ops::Sub<&Vec1> for Vec1 {
    type Output = Vec1;
    fn sub(self, rhs: &Vec1) -> Vec1 {
        (&self).sub(rhs)
    }
}
impl std::ops::Mul<&Vec1> for Vec1 {
    type Output = Vec1;
    fn mul(self, rhs: &Vec1) -> Vec1 {
        (&self).mul(rhs)
    }
}
impl std::ops::Add<Vec1> for Vec1 {
    type Output = Vec1;
    fn add(self, rhs: Vec1) -> Vec1 {
        (&self).add(&rhs)
    }
}
impl std::ops::Sub<Vec1> for Vec1 {
    type Output = Vec1;
    fn sub(self, rhs: Vec1) -> Vec1 {
        (&self).sub(&rhs)
    }
}
impl std::ops::Mul<Vec1> for Vec1 {
    type Output = Vec1;
    fn mul(self, rhs: Vec1) -> Vec1 {
        (&self).mul(&rhs)
    }
}

// ---------------------------------------------------------------------
// Mat2
// ---------------------------------------------------------------------

impl Mat2 {
    pub fn rows(&self) -> usize {
        self.node.shape.rows()
    }

    pub fn cols(&self) -> usize {
        self.node.shape.cols()
    }

    fn ew(&self, op: BinOp, rhs: &NodeRef) -> Mat2 {
        let shape = ew_shape(&self.node, rhs);
        Mat2 { ctx: self.ctx.clone(), node: bin_any(&self.ctx, op, &self.node, rhs, shape) }
    }

    /// Row `i` (virtual).
    pub fn row(&self, i: usize) -> Vec1 {
        assert!(i < self.rows(), "row out of range");
        Vec1 {
            ctx: self.ctx.clone(),
            node: Node::new(Op::Row(self.node.clone(), i), Shape::D1(self.cols()), DType::F64),
        }
    }

    /// Column `j` (virtual).
    pub fn col(&self, j: usize) -> Vec1 {
        assert!(j < self.cols(), "col out of range");
        Vec1 {
            ctx: self.ctx.clone(),
            node: Node::new(Op::Col(self.node.clone(), j), Shape::D1(self.rows()), DType::F64),
        }
    }

    /// `replace_col(c, i, v)` — functional column update.
    pub fn replace_col(&self, col: usize, v: &Vec1) -> Mat2 {
        assert!(col < self.cols());
        assert_eq!(v.len(), self.rows(), "replace_col length mismatch");
        Mat2 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::ReplaceCol { m: self.node.clone(), col, v: v.node.clone() },
                self.node.shape,
                DType::F64,
            ),
        }
    }

    /// `replace_row(c, i, v)` — functional row update.
    pub fn replace_row(&self, row: usize, v: &Vec1) -> Mat2 {
        assert!(row < self.rows());
        assert_eq!(v.len(), self.cols(), "replace_row length mismatch");
        Mat2 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::ReplaceRow { m: self.node.clone(), row, v: v.node.clone() },
                self.node.shape,
                DType::F64,
            ),
        }
    }

    /// `c(i,j) = s` — functional element store (the `arbb_mxm0` pattern).
    ///
    /// Forces eagerly: per-element stores are individual dispatches in
    /// ArBB too, which is exactly why `arbb_mxm0` is slow and serial.
    pub fn set_elem(&self, i: usize, j: usize, s: &Scal) -> Mat2 {
        assert!(i < self.rows() && j < self.cols());
        // The scalar must be materialised before the store executes.
        self.ctx.force(&s.node);
        let out = Mat2 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::SetElem { m: self.node.clone(), i, j, s: s.node.clone() },
                self.node.shape,
                DType::F64,
            ),
        };
        self.ctx.force(&out.node);
        out
    }

    /// Reduce along dimension 0 (within each row): the paper's
    /// `add_reduce(d, 0)`, producing `v(m) = Σ_k d(m,k)`.
    pub fn add_reduce_rows(&self) -> Vec1 {
        Vec1 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::ReduceRows(RedOp::Sum, self.node.clone()),
                Shape::D1(self.rows()),
                DType::F64,
            ),
        }
    }

    /// Reduce along dimension 1 (within each column): `v(k) = Σ_m d(m,k)`.
    pub fn add_reduce_cols(&self) -> Vec1 {
        Vec1 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::ReduceCols(RedOp::Sum, self.node.clone()),
                Shape::D1(self.cols()),
                DType::F64,
            ),
        }
    }

    /// Full reduction to a scalar.
    pub fn add_reduce_all(&self) -> Scal {
        Scal {
            ctx: self.ctx.clone(),
            node: Node::new(Op::ReduceAll(RedOp::Sum, self.node.clone()), Shape::Scalar, DType::F64),
        }
    }

    /// Flatten to a vector (virtual reshape).
    pub fn flatten(&self) -> Vec1 {
        Vec1 {
            ctx: self.ctx.clone(),
            node: Node::new(
                Op::Reshape(self.node.clone(), Shape::D1(self.rows() * self.cols())),
                Shape::D1(self.rows() * self.cols()),
                DType::F64,
            ),
        }
    }

    pub fn to_vec(&self) -> Vec<f64> {
        self.ctx.force(&self.node);
        self.node.data().expect("forced").as_f64().as_ref().clone()
    }

    pub fn read_to(&self, out: &mut [f64]) {
        self.ctx.force(&self.node);
        out.copy_from_slice(self.node.data().expect("forced").as_f64());
    }

    pub fn eval(&self) {
        self.ctx.force(&self.node);
    }
}

macro_rules! impl_mat_binop {
    ($trait:ident, $method:ident, $op:expr, $rhs:ty) => {
        impl std::ops::$trait<$rhs> for &Mat2 {
            type Output = Mat2;
            fn $method(self, rhs: $rhs) -> Mat2 {
                self.ew($op, &rhs.node)
            }
        }
    };
}

impl_mat_binop!(Add, add, BinOp::Add, &Mat2);
impl_mat_binop!(Sub, sub, BinOp::Sub, &Mat2);
impl_mat_binop!(Mul, mul, BinOp::Mul, &Mat2);
impl_mat_binop!(Div, div, BinOp::Div, &Mat2);
impl_mat_binop!(Add, add, BinOp::Add, &Scal);
impl_mat_binop!(Mul, mul, BinOp::Mul, &Scal);

impl std::ops::Add<Mat2> for Mat2 {
    type Output = Mat2;
    fn add(self, rhs: Mat2) -> Mat2 {
        (&self).add(&rhs)
    }
}
impl std::ops::Add<&Mat2> for Mat2 {
    type Output = Mat2;
    fn add(self, rhs: &Mat2) -> Mat2 {
        (&self).add(rhs)
    }
}
impl std::ops::Mul<&Mat2> for Mat2 {
    type Output = Mat2;
    fn mul(self, rhs: &Mat2) -> Mat2 {
        (&self).mul(rhs)
    }
}

// ---------------------------------------------------------------------
// Scal
// ---------------------------------------------------------------------

impl Scal {
    fn ew(&self, op: BinOp, rhs: &NodeRef) -> Scal {
        Scal { ctx: self.ctx.clone(), node: bin_any(&self.ctx, op, &self.node, rhs, Shape::Scalar) }
    }

    pub fn sqrt(&self) -> Scal {
        if let Some(f) = constfold::fold_un(UnOp::Sqrt, &self.node) {
            return Scal { ctx: self.ctx.clone(), node: f };
        }
        Scal {
            ctx: self.ctx.clone(),
            node: Node::new(Op::Un(UnOp::Sqrt, self.node.clone()), Shape::Scalar, DType::F64),
        }
    }

    /// Force evaluation and read the value (a `_while` condition read —
    /// the per-iteration sync point of the CG driver).
    pub fn value(&self) -> f64 {
        self.ctx.force(&self.node);
        if let Some(c) = super::plan::const_value(&self.node) {
            return c;
        }
        self.node.data().expect("forced scalar").as_f64()[0]
    }
}

macro_rules! impl_scal_binop {
    ($trait:ident, $method:ident, $op:expr) => {
        impl std::ops::$trait<&Scal> for &Scal {
            type Output = Scal;
            fn $method(self, rhs: &Scal) -> Scal {
                self.ew($op, &rhs.node)
            }
        }
        impl std::ops::$trait<f64> for &Scal {
            type Output = Scal;
            fn $method(self, rhs: f64) -> Scal {
                let c = Node::new(Op::ConstF64(rhs), Shape::Scalar, DType::F64);
                self.ew($op, &c)
            }
        }
    };
}

impl_scal_binop!(Add, add, BinOp::Add);
impl_scal_binop!(Sub, sub, BinOp::Sub);
impl_scal_binop!(Mul, mul, BinOp::Mul);
impl_scal_binop!(Div, div, BinOp::Div);

// ---------------------------------------------------------------------
// VecI64
// ---------------------------------------------------------------------

impl VecI64 {
    pub fn len(&self) -> usize {
        self.node.shape.len()
    }

    /// Owning context (index containers participate in `map` captures of
    /// the same context).
    pub fn context(&self) -> &Context {
        &self.ctx
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn to_vec(&self) -> Vec<i64> {
        // i64 containers are sources; no forcing machinery needed.
        self.node.data().expect("i64 source").as_i64().as_ref().clone()
    }
}

// ---------------------------------------------------------------------
// CplxV — split-complex helpers for the FFT port
// ---------------------------------------------------------------------

impl CplxV {
    pub fn len(&self) -> usize {
        self.re.len()
    }

    pub fn is_empty(&self) -> bool {
        self.re.is_empty()
    }

    pub fn add(&self, o: &CplxV) -> CplxV {
        CplxV { re: &self.re + &o.re, im: &self.im + &o.im }
    }

    pub fn sub(&self, o: &CplxV) -> CplxV {
        CplxV { re: &self.re - &o.re, im: &self.im - &o.im }
    }

    /// Complex element-wise multiply (the twiddle application):
    /// `(a+bi)(c+di) = (ac-bd) + (ad+bc)i`.
    pub fn mul(&self, o: &CplxV) -> CplxV {
        let re = (&self.re * &o.re) - (&self.im * &o.im);
        let im = (&self.re * &o.im) + (&self.im * &o.re);
        CplxV { re, im }
    }

    pub fn section_strided(&self, start: usize, len: usize, stride: usize) -> CplxV {
        CplxV {
            re: self.re.section_strided(start, len, stride),
            im: self.im.section_strided(start, len, stride),
        }
    }

    pub fn cat(&self, o: &CplxV) -> CplxV {
        CplxV { re: self.re.cat(&o.re), im: self.im.cat(&o.im) }
    }

    pub fn repeat(&self, times: usize) -> CplxV {
        CplxV { re: self.re.repeat(times), im: self.im.repeat(times) }
    }

    pub fn section(&self, start: usize, len: usize) -> CplxV {
        self.section_strided(start, len, 1)
    }

    /// Force both planes and return interleaved `[re0, im0, …]`.
    pub fn to_interleaved(&self) -> Vec<f64> {
        let re = self.re.to_vec();
        let im = self.im.to_vec();
        let mut out = Vec::with_capacity(re.len() * 2);
        for i in 0..re.len() {
            out.push(re[i]);
            out.push(im[i]);
        }
        out
    }
}

// ---------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::new()
    }

    #[test]
    fn elementwise_ops() {
        let c = ctx();
        let a = c.bind1(&[1.0, 2.0, 3.0]);
        let b = c.bind1(&[4.0, 5.0, 6.0]);
        assert_eq!((&a + &b).to_vec(), vec![5.0, 7.0, 9.0]);
        assert_eq!((&a - &b).to_vec(), vec![-3.0, -3.0, -3.0]);
        assert_eq!((&a * &b).to_vec(), vec![4.0, 10.0, 18.0]);
        assert_eq!((&b / &a).to_vec(), vec![4.0, 2.5, 2.0]);
        assert_eq!(a.scale(2.0).to_vec(), vec![2.0, 4.0, 6.0]);
        assert_eq!(a.neg().to_vec(), vec![-1.0, -2.0, -3.0]);
    }

    #[test]
    fn reductions_and_dot() {
        let c = ctx();
        let a = c.bind1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.add_reduce().value(), 10.0);
        assert_eq!(a.max_reduce().value(), 4.0);
        assert_eq!(a.min_reduce().value(), 1.0);
        let b = c.bind1(&[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.dot(&b).value(), 10.0);
    }

    #[test]
    fn sections_and_repeats() {
        let c = ctx();
        let a = c.bind1(&[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        assert_eq!(a.section(2, 3).to_vec(), vec![2.0, 3.0, 4.0]);
        assert_eq!(a.section_strided(0, 4, 2).to_vec(), vec![0.0, 2.0, 4.0, 6.0]);
        assert_eq!(a.section_strided(1, 4, 2).to_vec(), vec![1.0, 3.0, 5.0, 7.0]);
        let t = c.bind1(&[9.0, 8.0]);
        assert_eq!(t.repeat(3).to_vec(), vec![9.0, 8.0, 9.0, 8.0, 9.0, 8.0]);
    }

    #[test]
    fn matrix_row_col_and_reduce() {
        let c = ctx();
        // 2x3: [1 2 3; 4 5 6]
        let m = c.bind2(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        assert_eq!(m.row(1).to_vec(), vec![4.0, 5.0, 6.0]);
        assert_eq!(m.col(2).to_vec(), vec![3.0, 6.0]);
        assert_eq!(m.add_reduce_rows().to_vec(), vec![6.0, 15.0]);
        assert_eq!(m.add_reduce_cols().to_vec(), vec![5.0, 7.0, 9.0]);
        assert_eq!(m.add_reduce_all().value(), 21.0);
    }

    #[test]
    fn repeat_row_col_matrices() {
        let c = ctx();
        let v = c.bind1(&[1.0, 2.0, 3.0]);
        // every row is v
        assert_eq!(
            v.repeat_row(2).to_vec(),
            vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0],
        );
        // every column is v
        assert_eq!(
            v.repeat_col(2).to_vec(),
            vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0],
        );
    }

    #[test]
    fn replace_col_and_set_elem() {
        let c = ctx();
        let m = c.zeros2(2, 2);
        let v = c.bind1(&[7.0, 8.0]);
        let m2 = m.replace_col(1, &v);
        assert_eq!(m2.to_vec(), vec![0.0, 7.0, 0.0, 8.0]);
        let s = c.scalar(5.0);
        let m3 = m2.set_elem(0, 0, &s);
        assert_eq!(m3.to_vec(), vec![5.0, 7.0, 0.0, 8.0]);
    }

    #[test]
    fn cat_and_gather() {
        let c = ctx();
        let a = c.bind1(&[1.0, 2.0]);
        let b = c.bind1(&[3.0, 4.0, 5.0]);
        assert_eq!(a.cat(&b).to_vec(), vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let src = c.bind1(&[10.0, 20.0, 30.0]);
        let idx = c.bind_i64(&[2, 0, 1, 2]);
        assert_eq!(src.gather(&idx).to_vec(), vec![30.0, 10.0, 20.0, 30.0]);
    }

    #[test]
    fn scatter_and_segmented_reduce() {
        let c = ctx();
        let src = c.bind1(&[5.0, 6.0, 7.0]);
        let idx = c.bind_i64(&[4, 0, 2]);
        assert_eq!(src.scatter(&idx, 5).to_vec(), vec![6.0, 0.0, 7.0, 0.0, 5.0]);
        // segmented sum with an empty middle segment and a trailing
        // empty segment: identities, not garbage.
        let v = c.bind1(&[1.0, 2.0, 3.0, 4.0]);
        let segp = c.bind_i64(&[0, 2, 2, 4, 4]);
        assert_eq!(v.segmented_sum(&segp).to_vec(), vec![3.0, 0.0, 7.0, 0.0]);
        // non-sum reduction: per-segment max, empty segment -> -inf.
        let m = v.segmented_reduce(RedOp::Max, &segp).to_vec();
        assert_eq!(m[0], 2.0);
        assert_eq!(m[1], f64::NEG_INFINITY);
        assert_eq!(m[2], 4.0);
    }

    #[test]
    fn segmented_spmv_pattern() {
        // 2x3 CSR [[1,0,2],[0,3,0]] as gather + segmented sum.
        let c = ctx();
        let vals = c.bind1(&[1.0, 2.0, 3.0]);
        let indx = c.bind_i64(&[0, 2, 1]);
        let rowp = c.bind_i64(&[0, 2, 3]);
        let x = c.bind1(&[10.0, 100.0, 1000.0]);
        let g = x.gather(&indx);
        let y = (&vals * &g).segmented_sum(&rowp).to_vec();
        assert_eq!(y, vec![10.0 + 2000.0, 300.0]);
    }

    #[test]
    fn scalar_arithmetic_and_folding() {
        let c = ctx();
        let a = c.scalar(3.0);
        let b = c.scalar(4.0);
        let d = &(&a * &b) + 2.0;
        // fully folded at capture: no engine dispatch needed
        assert_eq!(d.value(), 14.0);
        assert_eq!(c.stats(|s| s.steps), 0, "const chain should fold at capture");
    }

    #[test]
    fn scalar_broadcast_over_vector() {
        let c = ctx();
        let a = c.bind1(&[1.0, 2.0, 3.0]);
        let s = a.add_reduce(); // 6.0
        let scaled = (&a * &s).to_vec();
        assert_eq!(scaled, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn mxm1_pattern() {
        // c_mi = Σ_n a_mn b_ni  via repeat_row + elementwise + reduce.
        let c = ctx();
        let n = 3;
        let a_host = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let b_host = [9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0];
        let a = c.bind2(&a_host, n, n);
        let b = c.bind2(&b_host, n, n);
        let mut cm = c.zeros2(n, n);
        for i in 0..n {
            let t = b.col(i).repeat_row(n);
            let d = &a * &t;
            cm = cm.replace_col(i, &d.add_reduce_rows());
        }
        let got = cm.to_vec();
        // reference
        let mut want = vec![0.0; n * n];
        for m in 0..n {
            for i in 0..n {
                for k in 0..n {
                    want[m * n + i] += a_host[m * n + k] * b_host[k * n + i];
                }
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn mxm2a_pattern() {
        // c += repeat_col(a.col(i), n) * repeat_row(b.row(i), n)
        let c = ctx();
        let n = 3;
        let a_host: Vec<f64> = (1..=9).map(|x| x as f64).collect();
        let b_host: Vec<f64> = (1..=9).rev().map(|x| x as f64).collect();
        let a = c.bind2(&a_host, n, n);
        let b = c.bind2(&b_host, n, n);
        let mut cm = a.col(0).repeat_col(n) * &b.row(0).repeat_row(n);
        for i in 1..n {
            cm = cm + (a.col(i).repeat_col(n) * &b.row(i).repeat_row(n));
        }
        let got = cm.to_vec();
        let mut want = vec![0.0; n * n];
        for m in 0..n {
            for j in 0..n {
                for k in 0..n {
                    want[m * n + j] += a_host[m * n + k] * b_host[k * n + j];
                }
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn cplx_mul() {
        let c = ctx();
        // (1+2i)(3+4i) = -5 + 10i
        let x = CplxV { re: c.bind1(&[1.0]), im: c.bind1(&[2.0]) };
        let y = CplxV { re: c.bind1(&[3.0]), im: c.bind1(&[4.0]) };
        let z = x.mul(&y);
        assert_eq!(z.re.to_vec(), vec![-5.0]);
        assert_eq!(z.im.to_vec(), vec![10.0]);
    }

    #[test]
    fn map_spmv_style() {
        use std::sync::Arc;
        let c = ctx();
        // 2x2 matrix [[1,2],[0,3]] in CSR
        let vals = c.bind1(&[1.0, 2.0, 3.0]);
        let invec = c.bind1(&[10.0, 100.0]);
        let indx = c.bind_i64(&[0, 1, 1]);
        let rowp = c.bind_i64(&[0, 2, 3]);
        let out = c.map(
            2,
            MapCaptures::new().f64(&vals).f64(&invec).i64(&indx).i64(&rowp),
            Arc::new(|args, row| {
                let (vals, invec) = (args.f(0), args.f(1));
                let (indx, rowp) = (args.i(0), args.i(1));
                let mut acc = 0.0;
                for k in rowp[row]..rowp[row + 1] {
                    acc += vals[k as usize] * invec[indx[k as usize] as usize];
                }
                acc
            }),
            4.0,
            48.0,
            "spmv_test",
        );
        assert_eq!(out.to_vec(), vec![210.0, 300.0]);
    }
}
