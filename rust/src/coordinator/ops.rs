//! Scalar operator vocabulary of the expression IR.
//!
//! These are the element-wise operators ArBB overloads on its dense
//! containers (§2 of the paper: "a wide variety of special operators for
//! e.g. element-wise operations, vector-scalar operations, collectives and
//! permutations").

/// Binary element-wise operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
}

impl BinOp {
    #[inline(always)]
    pub fn apply(self, a: f64, b: f64) -> f64 {
        match self {
            BinOp::Add => a + b,
            BinOp::Sub => a - b,
            BinOp::Mul => a * b,
            BinOp::Div => a / b,
            BinOp::Min => a.min(b),
            BinOp::Max => a.max(b),
        }
    }

    /// Apply over slices: `out[i] = op(a[i], b[i])`.
    ///
    /// Monomorphised per operator so the inner loop vectorises; this is the
    /// innermost loop of every fused element-wise kernel.
    #[inline]
    pub fn apply_slices(self, a: &[f64], b: &[f64], out: &mut [f64]) {
        debug_assert_eq!(a.len(), b.len());
        debug_assert_eq!(a.len(), out.len());
        match self {
            BinOp::Add => {
                for i in 0..out.len() {
                    out[i] = a[i] + b[i];
                }
            }
            BinOp::Sub => {
                for i in 0..out.len() {
                    out[i] = a[i] - b[i];
                }
            }
            BinOp::Mul => {
                for i in 0..out.len() {
                    out[i] = a[i] * b[i];
                }
            }
            BinOp::Div => {
                for i in 0..out.len() {
                    out[i] = a[i] / b[i];
                }
            }
            BinOp::Min => {
                for i in 0..out.len() {
                    out[i] = a[i].min(b[i]);
                }
            }
            BinOp::Max => {
                for i in 0..out.len() {
                    out[i] = a[i].max(b[i]);
                }
            }
        }
    }

    /// In-place variant: `acc[i] = op(acc[i], b[i])`.
    #[inline]
    pub fn apply_slices_inplace(self, acc: &mut [f64], b: &[f64]) {
        debug_assert_eq!(acc.len(), b.len());
        match self {
            BinOp::Add => {
                for i in 0..acc.len() {
                    acc[i] += b[i];
                }
            }
            BinOp::Sub => {
                for i in 0..acc.len() {
                    acc[i] -= b[i];
                }
            }
            BinOp::Mul => {
                for i in 0..acc.len() {
                    acc[i] *= b[i];
                }
            }
            BinOp::Div => {
                for i in 0..acc.len() {
                    acc[i] /= b[i];
                }
            }
            BinOp::Min => {
                for i in 0..acc.len() {
                    acc[i] = acc[i].min(b[i]);
                }
            }
            BinOp::Max => {
                for i in 0..acc.len() {
                    acc[i] = acc[i].max(b[i]);
                }
            }
        }
    }

    /// Scalar-on-the-right variant: `out[i] = op(a[i], s)`.
    #[inline]
    pub fn apply_slice_scalar(self, a: &[f64], s: f64, out: &mut [f64]) {
        debug_assert_eq!(a.len(), out.len());
        match self {
            BinOp::Add => {
                for i in 0..out.len() {
                    out[i] = a[i] + s;
                }
            }
            BinOp::Sub => {
                for i in 0..out.len() {
                    out[i] = a[i] - s;
                }
            }
            BinOp::Mul => {
                for i in 0..out.len() {
                    out[i] = a[i] * s;
                }
            }
            BinOp::Div => {
                for i in 0..out.len() {
                    out[i] = a[i] / s;
                }
            }
            BinOp::Min => {
                for i in 0..out.len() {
                    out[i] = a[i].min(s);
                }
            }
            BinOp::Max => {
                for i in 0..out.len() {
                    out[i] = a[i].max(s);
                }
            }
        }
    }

    /// `out[i] = op(out[i], s)` — scalar right operand, in place. `Div`
    /// multiplies by the reciprocal, computed once; that choice is part
    /// of the cross-backend bit contract (see
    /// [`crate::coordinator::engine::backend`]).
    #[inline]
    pub fn apply_slice_scalar_inplace(self, out: &mut [f64], s: f64) {
        match self {
            BinOp::Add => out.iter_mut().for_each(|x| *x += s),
            BinOp::Sub => out.iter_mut().for_each(|x| *x -= s),
            BinOp::Mul => out.iter_mut().for_each(|x| *x *= s),
            BinOp::Div => {
                let inv = 1.0 / s;
                out.iter_mut().for_each(|x| *x *= inv)
            }
            BinOp::Min => out.iter_mut().for_each(|x| *x = x.min(s)),
            BinOp::Max => out.iter_mut().for_each(|x| *x = x.max(s)),
        }
    }

    /// Estimated FLOPs per element (for the virtual-time simulator).
    pub fn flops(self) -> f64 {
        match self {
            BinOp::Div => 4.0, // div is several times an add/mul on WSM-EX
            _ => 1.0,
        }
    }
}

/// Unary element-wise operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnOp {
    Neg,
    Abs,
    Sqrt,
    Exp,
    Ln,
    Recip,
}

impl UnOp {
    #[inline(always)]
    pub fn apply(self, a: f64) -> f64 {
        match self {
            UnOp::Neg => -a,
            UnOp::Abs => a.abs(),
            UnOp::Sqrt => a.sqrt(),
            UnOp::Exp => a.exp(),
            UnOp::Ln => a.ln(),
            UnOp::Recip => 1.0 / a,
        }
    }

    #[inline]
    pub fn apply_slices(self, a: &[f64], out: &mut [f64]) {
        debug_assert_eq!(a.len(), out.len());
        match self {
            UnOp::Neg => {
                for i in 0..out.len() {
                    out[i] = -a[i];
                }
            }
            UnOp::Abs => {
                for i in 0..out.len() {
                    out[i] = a[i].abs();
                }
            }
            UnOp::Sqrt => {
                for i in 0..out.len() {
                    out[i] = a[i].sqrt();
                }
            }
            UnOp::Exp => {
                for i in 0..out.len() {
                    out[i] = a[i].exp();
                }
            }
            UnOp::Ln => {
                for i in 0..out.len() {
                    out[i] = a[i].ln();
                }
            }
            UnOp::Recip => {
                for i in 0..out.len() {
                    out[i] = 1.0 / a[i];
                }
            }
        }
    }

    /// In-place variant: `out[i] = op(out[i])` — the form both the tree
    /// interpreter and the tape VM apply to a register block.
    #[inline]
    pub fn apply_slice_inplace(self, out: &mut [f64]) {
        match self {
            UnOp::Neg => out.iter_mut().for_each(|x| *x = -*x),
            UnOp::Abs => out.iter_mut().for_each(|x| *x = x.abs()),
            UnOp::Sqrt => out.iter_mut().for_each(|x| *x = x.sqrt()),
            UnOp::Exp => out.iter_mut().for_each(|x| *x = x.exp()),
            UnOp::Ln => out.iter_mut().for_each(|x| *x = x.ln()),
            UnOp::Recip => out.iter_mut().for_each(|x| *x = 1.0 / *x),
        }
    }

    pub fn flops(self) -> f64 {
        match self {
            UnOp::Neg | UnOp::Abs => 1.0,
            UnOp::Sqrt | UnOp::Recip => 8.0,
            UnOp::Exp | UnOp::Ln => 20.0,
        }
    }
}

/// Reduction operators (collectives).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RedOp {
    Sum,
    Prod,
    Min,
    Max,
}

impl RedOp {
    #[inline(always)]
    pub fn identity(self) -> f64 {
        match self {
            RedOp::Sum => 0.0,
            RedOp::Prod => 1.0,
            RedOp::Min => f64::INFINITY,
            RedOp::Max => f64::NEG_INFINITY,
        }
    }

    #[inline(always)]
    pub fn fold(self, acc: f64, x: f64) -> f64 {
        match self {
            RedOp::Sum => acc + x,
            RedOp::Prod => acc * x,
            RedOp::Min => acc.min(x),
            RedOp::Max => acc.max(x),
        }
    }

    /// Reduce a slice — the **canonical association contract** of the
    /// runtime's reductions. For `Sum` the order is the 4-lane unroll
    /// below (lane `j` accumulates elements `j, j+4, …`, lanes merge
    /// left-to-right, remainder folds serially); every
    /// [`crate::coordinator::engine::backend::Backend`] must reproduce
    /// it bit for bit (a SIMD backend's 4-wide accumulator vector *is*
    /// this order), so results never depend on the selected backend.
    #[inline]
    pub fn fold_slice(self, xs: &[f64]) -> f64 {
        match self {
            // 4-way unrolled sum: breaks the serial FP dependence chain so
            // the loop can keep multiple adds in flight (and autovectorise).
            RedOp::Sum => {
                let mut acc = [0.0f64; 4];
                let chunks = xs.chunks_exact(4);
                let rem = chunks.remainder();
                for c in chunks {
                    acc[0] += c[0];
                    acc[1] += c[1];
                    acc[2] += c[2];
                    acc[3] += c[3];
                }
                let mut s = acc[0] + acc[1] + acc[2] + acc[3];
                for &x in rem {
                    s += x;
                }
                s
            }
            _ => xs.iter().copied().fold(self.identity(), |a, x| self.fold(a, x)),
        }
    }

    /// Merge one ≤BLOCK chunk of segment values into a running segment
    /// accumulator: the canonical association contract of the segmented
    /// reducers. Every segmented executor — the tree-interpreter
    /// reference, the blocked tape path, the fused gather-mul-sum path
    /// and the contiguity-run path — must produce chunk values
    /// bit-identical to [`RedOp::fold_slice`] and merge them through
    /// this, so a segment's result never depends on which executor ran.
    #[inline]
    pub fn fold_segment_chunk(self, acc: f64, chunk: &[f64]) -> f64 {
        self.fold(acc, self.fold_slice(chunk))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binop_apply_matches_slices() {
        let a = [1.0, 2.0, -3.0, 0.5];
        let b = [4.0, -1.0, 2.0, 0.25];
        for op in [BinOp::Add, BinOp::Sub, BinOp::Mul, BinOp::Div, BinOp::Min, BinOp::Max] {
            let mut out = [0.0; 4];
            op.apply_slices(&a, &b, &mut out);
            for i in 0..4 {
                assert_eq!(out[i], op.apply(a[i], b[i]), "{op:?} elem {i}");
            }
            let mut acc = a;
            op.apply_slices_inplace(&mut acc, &b);
            assert_eq!(acc, out, "{op:?} inplace");
        }
    }

    #[test]
    fn unop_apply_matches_slices() {
        let a = [1.0, 4.0, 0.25, 9.0];
        for op in [UnOp::Neg, UnOp::Abs, UnOp::Sqrt, UnOp::Exp, UnOp::Ln, UnOp::Recip] {
            let mut out = [0.0; 4];
            op.apply_slices(&a, &mut out);
            for i in 0..4 {
                assert_eq!(out[i], op.apply(a[i]), "{op:?} elem {i}");
            }
        }
    }

    #[test]
    fn reductions() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(RedOp::Sum.fold_slice(&xs), 15.0);
        assert_eq!(RedOp::Prod.fold_slice(&xs), 120.0);
        assert_eq!(RedOp::Min.fold_slice(&xs), 1.0);
        assert_eq!(RedOp::Max.fold_slice(&xs), 5.0);
        assert_eq!(RedOp::Sum.fold_slice(&[]), 0.0);
        // unrolled sum handles remainders
        let ys: Vec<f64> = (1..=11).map(|x| x as f64).collect();
        assert_eq!(RedOp::Sum.fold_slice(&ys), 66.0);
    }
}
