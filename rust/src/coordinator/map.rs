//! ArBB `map()`: elemental functions applied across container elements.
//!
//! The paper's `arbb_spmv1` kernel (§3.2) maps a scalar elemental function
//! — "loop over one row of the input matrix, accumulate `matvals[i] *
//! invec[indx[i]]`" — across all `nrows` elements of the output vector.
//! `map()` may only occur inside a captured closure, and the elemental
//! function has random (gather) access to whole captured containers.
//!
//! We reproduce the same construct: the elemental function is a rust
//! closure over immutable slices of the captured containers, invoked with
//! the output element index. The engines chunk the output space across
//! workers; each invocation is independent, which is what makes `map`
//! ArBB's general escape hatch for irregular data access.

use std::fmt;
use std::sync::Arc;

use super::node::NodeRef;

/// Resolved argument slices handed to an elemental function.
///
/// Index order matches the order of `captures` at map creation.
pub struct MapArgs<'a> {
    pub f64s: Vec<&'a [f64]>,
    pub i64s: Vec<&'a [i64]>,
}

impl<'a> MapArgs<'a> {
    /// The `k`-th captured f64 container.
    #[inline(always)]
    pub fn f(&self, k: usize) -> &'a [f64] {
        self.f64s[k]
    }

    /// The `k`-th captured i64 container.
    #[inline(always)]
    pub fn i(&self, k: usize) -> &'a [i64] {
        self.i64s[k]
    }
}

/// Type of an elemental function: `(args, element_index) -> value`.
pub type Elemental = dyn Fn(&MapArgs<'_>, usize) -> f64 + Send + Sync;

/// A captured `map()` invocation.
pub struct MapFn {
    /// Captured containers (resolved to slices before execution).
    pub captures: Vec<NodeRef>,
    /// The elemental function.
    pub f: Arc<Elemental>,
    /// Estimated FLOPs per output element (for the scaling simulator);
    /// irregular kernels pass the *average* row cost.
    pub flops_per_elem: f64,
    /// Estimated bytes touched per output element.
    pub bytes_per_elem: f64,
    /// Debug label (shows up in engine stats).
    pub label: &'static str,
}

impl fmt::Debug for MapFn {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("MapFn")
            .field("label", &self.label)
            .field("captures", &self.captures.len())
            .field("flops_per_elem", &self.flops_per_elem)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_args_access() {
        let a = vec![1.0, 2.0];
        let b = vec![3i64, 4];
        let args = MapArgs { f64s: vec![&a], i64s: vec![&b] };
        assert_eq!(args.f(0)[1], 2.0);
        assert_eq!(args.i(0)[0], 3);
    }

    #[test]
    fn elemental_is_callable() {
        let f: Arc<Elemental> = Arc::new(|args, i| args.f(0)[i] * 2.0);
        let a = vec![1.0, 2.0, 3.0];
        let args = MapArgs { f64s: vec![&a], i64s: vec![] };
        assert_eq!(f(&args, 2), 6.0);
    }
}
