//! The buffer planner: program values → arena slots.
//!
//! Every invocation of a captured [`super::Program`] runs out of a fixed
//! set of f64 slot buffers sized here, at capture time — the executor
//! never allocates. Three assignment rules:
//!
//!  * **Parameters** get no slot (read straight from request buffers).
//!  * **Carried vectors** get a dedicated slot for the program's
//!    lifetime; a carried vector that is ever *staged* (its update reads
//!    itself through a view) gets a **front/back slot pair** —
//!    double-buffering. This is what turns the FFT's per-stage
//!    `cat(up, down)` materialisation (a fresh n-element buffer per
//!    stage, 2·log₂n allocations per transform) into two fixed slots
//!    per plane and an O(1) flip per stage.
//!  * **Temporaries** are assigned by liveness: a slot frees at its
//!    value's last read and is reused by the next same-length
//!    temporary. Frees inside a `_for` body are deferred to the loop
//!    exit when the value was defined before the loop (the back edge
//!    re-reads it on every trip).

use super::{PE, PNode, Rd, Stmt, VKind, ValInfo, Vect};

/// Where a program value lives at replay time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Storage {
    /// Parameters: no slot.
    None,
    Single(usize),
    /// Index into the pair table (front/back slots + runtime flip bit).
    Pair(usize),
}

#[derive(Debug)]
pub(crate) struct BufferPlan {
    /// Per-value storage assignment (indexed by `Vect`).
    pub(crate) storage: Vec<Storage>,
    /// Capture-time length of every slot.
    pub(crate) slot_lens: Vec<usize>,
    /// Front/back slot pairs of double-buffered carried vectors.
    pub(crate) pairs: Vec<(usize, usize)>,
}

/// A value use event at a linear walk position.
struct Live {
    def: usize,
    last: usize,
}

/// Assign slots to every carried and temporary value.
pub(crate) fn plan_buffers(
    vals: &[ValInfo],
    root: &[PNode],
    stmts: &[Stmt],
    outputs: &[Rd],
) -> BufferPlan {
    let mut storage = vec![Storage::None; vals.len()];
    let mut slot_lens: Vec<usize> = Vec::new();
    let mut pairs: Vec<(usize, usize)> = Vec::new();

    // Carried vectors: dedicated slots (pairs when double-buffered).
    for (i, v) in vals.iter().enumerate() {
        if v.kind != VKind::Carried {
            continue;
        }
        if v.paired {
            let a = slot_lens.len();
            slot_lens.push(v.len);
            let b = slot_lens.len();
            slot_lens.push(v.len);
            pairs.push((a, b));
            storage[i] = Storage::Pair(pairs.len() - 1);
        } else {
            slot_lens.push(v.len);
            storage[i] = Storage::Single(slot_lens.len() - 1);
        }
    }

    // Temporaries: liveness over a linear walk of the structure
    // (uniform `_for` bodies are walked once; the back-edge extension
    // below covers replays).
    let mut pos = 0usize;
    let mut lives: Vec<Option<Live>> = (0..vals.len()).map(|_| None).collect();
    let mut loop_spans: Vec<(usize, usize)> = Vec::new();
    walk(root, stmts, &mut pos, &mut lives, &mut loop_spans);
    let end = pos;
    for r in outputs {
        if let Rd::Val(v) = r {
            touch(&mut lives, *v, end);
        }
    }
    // Back-edge extension: a value defined before a loop but read inside
    // it stays live until the loop exits. Loops can nest, so iterate to
    // a fixpoint (spans are few; this converges immediately in
    // practice).
    loop {
        let mut changed = false;
        for live in lives.iter_mut().flatten() {
            for &(s, e) in &loop_spans {
                if live.def < s && live.last >= s && live.last < e {
                    live.last = e;
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }

    // Greedy slot assignment by (position, exact length) with a free
    // list.
    let mut events: Vec<(usize, bool, usize)> = Vec::new(); // (pos, is_def, val)
    for (i, l) in lives.iter().enumerate() {
        if vals[i].kind != VKind::Temp {
            continue;
        }
        if let Some(l) = l {
            events.push((l.def, true, i));
            events.push((l.last, false, i));
        }
    }
    // Frees at a position happen before defs at the same position would
    // be wrong (a statement reads its sources while writing its output,
    // and the output slot must not alias a dying source), so defs sort
    // first at equal positions: sort by (pos, is_def desc? ) — actually
    // a def at position p must NOT take a slot freed at the same p.
    events.sort_by_key(|&(p, is_def, v)| (p, !is_def as usize, v));
    let mut free: Vec<(usize, usize)> = Vec::new(); // (len, slot)
    for (_, is_def, v) in events {
        if is_def {
            let len = vals[v].len;
            let slot = match free.iter().position(|&(l, _)| l == len) {
                Some(k) => free.swap_remove(k).1,
                None => {
                    slot_lens.push(len);
                    slot_lens.len() - 1
                }
            };
            storage[v] = Storage::Single(slot);
        } else if let Storage::Single(s) = storage[v] {
            free.push((vals[v].len, s));
        }
    }

    BufferPlan { storage, slot_lens, pairs }
}

fn touch(lives: &mut [Option<Live>], v: Vect, pos: usize) {
    if let Some(l) = lives[v.0].as_mut() {
        l.last = l.last.max(pos);
    } else {
        lives[v.0] = Some(Live { def: pos, last: pos });
    }
}

fn touch_rd(lives: &mut [Option<Live>], r: Rd, pos: usize) {
    if let Rd::Val(v) = r {
        touch(lives, v, pos);
    }
}

fn touch_expr(lives: &mut [Option<Live>], e: &PE, pos: usize) {
    match e {
        PE::Read { src, .. } | PE::Gather { src, .. } => touch_rd(lives, *src, pos),
        PE::Bin(_, a, b) => {
            touch_expr(lives, a, pos);
            touch_expr(lives, b, pos);
        }
        PE::Un(_, a) => touch_expr(lives, a, pos),
        PE::Splat(_) | PE::Const(_) | PE::Acc => {}
    }
}

fn walk(
    nodes: &[PNode],
    stmts: &[Stmt],
    pos: &mut usize,
    lives: &mut [Option<Live>],
    loop_spans: &mut Vec<(usize, usize)>,
) {
    for n in nodes {
        match n {
            PNode::Stmt(i) => {
                let p = *pos;
                *pos += 1;
                match &stmts[*i] {
                    Stmt::Emit { dst, expr, .. } => {
                        touch_expr(lives, &expr.0, p);
                        touch(lives, *dst, p);
                    }
                    Stmt::Commit { dst } => touch(lives, *dst, p),
                    Stmt::Spmv { dst, x, .. } => {
                        touch_rd(lives, *x, p);
                        touch(lives, *dst, p);
                    }
                    Stmt::Dot { a, b, .. } => {
                        touch_rd(lives, *a, p);
                        touch_rd(lives, *b, p);
                    }
                    Stmt::SBin { .. } | Stmt::SSet { .. } => {}
                }
            }
            PNode::For { bodies, .. } => {
                let start = *pos;
                for b in bodies {
                    walk(b, stmts, pos, lives, loop_spans);
                }
                loop_spans.push((start, *pos));
            }
        }
    }
}
