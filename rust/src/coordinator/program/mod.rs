//! Program capture: `arbb::call()`-style closure capture with a
//! structured `_for` loop IR and a double-buffered buffer plan.
//!
//! The interactive DSL ([`crate::coordinator::api`]) dispatches one fused
//! graph per forced expression — faithful to the paper's measurements,
//! but it re-captures, re-plans and re-allocates on every call, and the
//! FFT's stage loop pays a full `cat(up, down)` materialisation per
//! stage. ArBB's real execution model is *whole-function* capture: a
//! closure (including its `_for` loops, §3.3/§3.4) is JIT-compiled once
//! and invoked many times. This module is that missing layer:
//!
//!  * [`ProgramBuilder`] records a multi-step computation — bound
//!    parameters, loop-carried vectors, baked constants, and a
//!    structured `_for` construct ([`ProgramBuilder::repeat`] /
//!    [`ProgramBuilder::for_each`]) whose trip count is resolved at
//!    capture — into a [`Program`] IR of planned steps.
//!  * A buffer planner ([`plan`]) assigns loop-carried and intermediate
//!    vectors to a small set of arena slots. A carried vector whose
//!    update reads *itself through a view* (the FFT's even/odd
//!    sections) gets a **front/back slot pair**: the update becomes
//!    region writes into the back buffer plus an O(1) flip — the
//!    per-stage `cat(up, down)` materialisation disappears. A carried
//!    vector whose update reads itself only element-wise (CG's
//!    `x += alpha*p`) updates **in place** through the tape's
//!    [`Acc`](PExpr::acc) register.
//!  * Each step's expression compiles **once at capture** into a
//!    [`TapeProgram`](super::engine::eval::TapeProgram); the executor
//!    ([`super::engine::program`]) replays the whole loop nest per
//!    invocation from a recycled state arena, so a steady-state replay
//!    performs **zero heap allocations** (asserted by
//!    `rust/tests/serve_alloc.rs`).
//!
//! # Semantics
//!
//! A program is a sequence of statements over three value kinds:
//! **parameters** (rebound per invocation), **carried** vectors
//! (persistent slots, the `_for` loop state), and **temporaries**
//! (slot-recycled intermediates). Statements are recorded by running
//! ordinary rust code once — exactly like ArBB capture runs the C++
//! closure once — with `_for` bodies bracketed by
//! [`ProgramBuilder::repeat`] (body captured once, replayed `trip`
//! times) or [`ProgramBuilder::for_each`] (per-iteration capture for
//! stage loops whose geometry changes, like mod2f's twiddle sections).
//!
//! Double-buffered updates are staged explicitly: [`stage_region`]
//! writes into the back buffer while reads still see the front;
//! [`commit`] validates that the staged regions tile the vector exactly
//! and flips the pair. [`assign`] auto-stages when the expression reads
//! the destination; [`update`] is the in-place `Acc` form.
//!
//! [`stage_region`]: ProgramBuilder::stage_region
//! [`commit`]: ProgramBuilder::commit
//! [`assign`]: ProgramBuilder::assign
//! [`update`]: ProgramBuilder::update
//!
//! # Example: a captured axpy-like update loop
//!
//! ```
//! use arbb_rs::coordinator::program::{PExpr, ProgramBuilder};
//!
//! let mut pb = ProgramBuilder::new();
//! let x0 = pb.param(4);
//! let acc = pb.carried(4);
//! pb.assign(acc, PExpr::read(x0));
//! pb.repeat(3, |pb| {
//!     // acc *= 3  (element-wise: in-place slot reuse via Acc)
//!     pb.update(acc, PExpr::acc() * PExpr::lit(3.0));
//! });
//! let prog = pb.finish().unwrap();
//! let out = prog.invoke(&[&[1.0, 2.0, 3.0, 4.0]]).unwrap();
//! assert_eq!(out, vec![27.0, 54.0, 81.0, 108.0]);
//! ```

pub mod plan;

use std::sync::Arc;

use super::engine::eval::KTree;
use super::engine::program::{CNode, CStep, EmitStep, PBind, PDst};
pub use super::engine::program::{ProgStats, Program};
use super::ops::{BinOp, UnOp};
use super::shape::View;
use crate::sparse::Csr;

/// Handle to a program vector value: a parameter, a loop-carried vector
/// or a temporary. Copyable capture-time token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Vect(pub(crate) usize);

/// Handle to a program scalar register (reduction results, `alpha`/`beta`
/// of the CG loop).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sval(pub(crate) usize);

/// Handle to a baked (capture-time constant) f64 vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BakedVec(pub(crate) usize);

/// Handle to a baked i64 index table (gather indices, CSR structure).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BakedInts(pub(crate) usize);

/// A readable operand: a program value or a baked constant.
#[derive(Debug, Clone, Copy)]
pub enum Rd {
    Val(Vect),
    Baked(BakedVec),
}

impl From<Vect> for Rd {
    fn from(v: Vect) -> Rd {
        Rd::Val(v)
    }
}

impl From<BakedVec> for Rd {
    fn from(b: BakedVec) -> Rd {
        Rd::Baked(b)
    }
}

/// How a leaf reads its source relative to the statement's output index
/// space (length `L`): composed into an affine [`View`] at capture, with
/// the same composition rules as the fusion pass.
#[derive(Debug, Clone, Copy)]
enum PView {
    /// Identity: source length must equal `L`.
    Full,
    /// `section(src, start, L, stride)` — the FFT's even/odd splits.
    Section { start: usize, stride: usize },
    /// `repeat(section(src, 0, period), ·)` — cyclic tile (twiddles).
    Tile { period: usize },
}

impl PView {
    fn to_view(self, out_len: usize) -> View {
        match self {
            PView::Full => View::identity(out_len),
            PView::Section { start, stride } => View {
                base: start,
                row_stride: out_len * stride,
                col_stride: stride,
                out_cols: out_len,
                modulo: None,
            },
            PView::Tile { period } => View {
                base: 0,
                row_stride: out_len,
                col_stride: 1,
                out_cols: out_len,
                modulo: Some(period),
            },
        }
    }

    /// Largest source index this view can touch for an `out_len` space.
    fn max_src_index(self, out_len: usize) -> usize {
        match self {
            PView::Full => out_len - 1,
            PView::Section { start, stride } => start + (out_len - 1) * stride,
            PView::Tile { period } => period - 1,
        }
    }
}

/// A capture-time expression tree over program values. Compiled once per
/// statement into a tape; cheap to clone while building.
#[derive(Debug, Clone)]
pub struct PExpr(PE);

#[derive(Debug, Clone)]
enum PE {
    Read { src: Rd, view: PView },
    Gather { src: Rd, idx: BakedInts },
    Splat(Sval),
    Const(f64),
    Acc,
    Bin(BinOp, Box<PE>, Box<PE>),
    Un(UnOp, Box<PE>),
}

impl PExpr {
    /// Identity read of a full vector.
    pub fn read(src: impl Into<Rd>) -> PExpr {
        PExpr(PE::Read { src: src.into(), view: PView::Full })
    }

    /// Strided section: element `k` reads `src[start + k*stride]` (the
    /// FFT's even/odd splits use stride 2).
    pub fn sec(src: impl Into<Rd>, start: usize, stride: usize) -> PExpr {
        PExpr(PE::Read { src: src.into(), view: PView::Section { start, stride } })
    }

    /// Cyclic tile: element `k` reads `src[k mod period]` (the FFT's
    /// `repeat(section(twiddles, 0, m), i)`).
    pub fn tile(src: impl Into<Rd>, period: usize) -> PExpr {
        PExpr(PE::Read { src: src.into(), view: PView::Tile { period } })
    }

    /// Gather: element `k` reads `src[idx[k]]` through a baked index
    /// table (the FFT's initial tangling permutation).
    pub fn gather(src: impl Into<Rd>, idx: BakedInts) -> PExpr {
        PExpr(PE::Gather { src: src.into(), idx })
    }

    /// Broadcast of a scalar register (CG's `alpha`/`beta`).
    pub fn splat(s: Sval) -> PExpr {
        PExpr(PE::Splat(s))
    }

    /// Scalar constant.
    pub fn lit(c: f64) -> PExpr {
        PExpr(PE::Const(c))
    }

    /// The destination's current value, read in place (tape `Acc`
    /// register). Only valid inside [`ProgramBuilder::update`], and only
    /// on the **left spine** of the expression — the tape evaluates
    /// left-first into the output register, so a left-spine `Acc` is the
    /// in-place read-modify-write and anything else would read
    /// partially-overwritten data (rejected at capture).
    pub fn acc() -> PExpr {
        PExpr(PE::Acc)
    }

    /// Unary operator application.
    pub fn un(self, op: UnOp) -> PExpr {
        PExpr(PE::Un(op, Box::new(self.0)))
    }

    fn bin(op: BinOp, a: PExpr, b: PExpr) -> PExpr {
        PExpr(PE::Bin(op, Box::new(a.0), Box::new(b.0)))
    }
}

macro_rules! impl_pexpr_op {
    ($trait:ident, $method:ident, $op:expr) => {
        impl std::ops::$trait<PExpr> for PExpr {
            type Output = PExpr;
            fn $method(self, rhs: PExpr) -> PExpr {
                PExpr::bin($op, self, rhs)
            }
        }
    };
}

impl_pexpr_op!(Add, add, BinOp::Add);
impl_pexpr_op!(Sub, sub, BinOp::Sub);
impl_pexpr_op!(Mul, mul, BinOp::Mul);
impl_pexpr_op!(Div, div, BinOp::Div);

/// A CSR matrix baked into a program (structure and values are
/// capture-time constants; see [`ProgramBuilder::bake_csr`]).
#[derive(Debug, Clone, Copy)]
pub struct BakedCsr {
    pub(crate) vals: BakedVec,
    pub(crate) indx: BakedInts,
    pub(crate) rowp: BakedInts,
    pub nrows: usize,
    pub ncols: usize,
}

// ---------------------------------------------------------------------
// capture-time IR
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum VKind {
    Param(usize),
    Carried,
    Temp,
}

#[derive(Debug)]
pub(crate) struct ValInfo {
    pub(crate) len: usize,
    pub(crate) kind: VKind,
    pub(crate) written: bool,
    /// Carried value updated through self-reading views: gets a
    /// front/back slot pair (double-buffering).
    pub(crate) paired: bool,
    /// Regions staged since the last commit (builder-time validation).
    staged: Vec<(usize, usize)>,
}

/// One recorded statement (pre-buffer-plan IR).
#[derive(Debug)]
pub(crate) enum Stmt {
    /// Fused element-wise write of `expr` into `dst[off..off+len]`.
    /// `staged` writes target the back buffer of a pair.
    Emit { dst: Vect, off: usize, len: usize, expr: PExpr, staged: bool },
    /// Flip a double-buffered carried vector (recorded by `commit`).
    Commit { dst: Vect },
    /// `dst[r] = Σ_k vals[k] · x[indx[k]]` over CSR row `r` — replicates
    /// [`crate::sparse::Csr::spmv`] bit-for-bit.
    Spmv { dst: Vect, csr: BakedCsr, x: Rd },
    /// `dst = Σ a·b` via [`crate::kernels::blas1::dot`] (bit-identical
    /// to the host CG driver's reductions).
    Dot { dst: Sval, a: Rd, b: Rd },
    /// Scalar register arithmetic.
    SBin { op: BinOp, dst: Sval, a: Sval, b: Sval },
    /// Scalar register copy (carried-scalar rebind at iteration end).
    SSet { dst: Sval, src: Sval },
}

/// Structured statement tree: the `_for` loop IR.
#[derive(Debug)]
pub(crate) enum PNode {
    Stmt(usize),
    /// `_for` with a capture-resolved trip count. `uniform` bodies hold
    /// one body replayed `trip` times; staged bodies hold `trip`
    /// per-iteration bodies (geometry-changing loops).
    For { trip: usize, uniform: bool, bodies: Vec<Vec<PNode>> },
}

/// Records a multi-step computation into a [`Program`]. See the module
/// docs for the capture model; API misuse (reading an unwritten value,
/// out-of-range views, incomplete staged regions) panics at capture
/// time like the eager DSL's shape asserts.
pub struct ProgramBuilder {
    param_lens: Vec<usize>,
    baked_f: Vec<Arc<Vec<f64>>>,
    baked_i: Vec<Arc<Vec<i64>>>,
    vals: Vec<ValInfo>,
    n_sregs: usize,
    stmts: Vec<Stmt>,
    root: Vec<PNode>,
    frames: Vec<Vec<PNode>>,
    outputs: Vec<Rd>,
    /// Kernel backend every statement tape compiles against at
    /// [`ProgramBuilder::finish`] (the process-wide active backend by
    /// default; tests force scalar vs SIMD side by side).
    backend: &'static dyn super::engine::backend::Backend,
}

impl Default for ProgramBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ProgramBuilder {
    pub fn new() -> ProgramBuilder {
        ProgramBuilder {
            param_lens: Vec::new(),
            baked_f: Vec::new(),
            baked_i: Vec::new(),
            vals: Vec::new(),
            n_sregs: 0,
            stmts: Vec::new(),
            root: Vec::new(),
            frames: Vec::new(),
            outputs: Vec::new(),
            backend: super::engine::backend::active(),
        }
    }

    /// Force the kernel backend the compiled statement tapes run on
    /// (all backends are bit-identical by contract; this exists for the
    /// cross-backend property suites and ablations).
    pub fn set_backend(&mut self, bk: &'static dyn super::engine::backend::Backend) {
        self.backend = bk;
    }

    /// Declare an f64 vector parameter of length `len`, rebound on every
    /// invocation (the ArBB closure's bound argument).
    pub fn param(&mut self, len: usize) -> Vect {
        assert!(len > 0, "program: zero-length parameter");
        let p = self.param_lens.len();
        self.param_lens.push(len);
        self.vals.push(ValInfo {
            len,
            kind: VKind::Param(p),
            written: true,
            paired: false,
            staged: Vec::new(),
        });
        Vect(self.vals.len() - 1)
    }

    /// Bake a capture-time f64 constant (twiddle tables, CSR values).
    pub fn bake(&mut self, data: &[f64]) -> BakedVec {
        self.baked_f.push(Arc::new(data.to_vec()));
        BakedVec(self.baked_f.len() - 1)
    }

    /// Bake a capture-time i64 index table (gather indices, row
    /// pointers).
    pub fn bake_i64(&mut self, data: &[i64]) -> BakedInts {
        self.baked_i.push(Arc::new(data.to_vec()));
        BakedInts(self.baked_i.len() - 1)
    }

    /// Bake a CSR matrix (values, column indices and row pointers become
    /// capture-time constants shared read-only across invocations).
    pub fn bake_csr(&mut self, m: &Csr) -> BakedCsr {
        assert_eq!(m.rowp.len(), m.nrows + 1, "bake_csr: malformed row pointers");
        assert_eq!(m.vals.len(), m.indx.len(), "bake_csr: vals/indx length mismatch");
        assert!(
            m.indx.iter().all(|&c| c >= 0 && (c as usize) < m.ncols),
            "bake_csr: column index out of range"
        );
        super::engine::validate_segp(&m.rowp, m.nrows, m.vals.len())
            .expect("bake_csr: malformed row pointers");
        BakedCsr {
            vals: self.bake(&m.vals),
            indx: self.bake_i64(&m.indx),
            rowp: self.bake_i64(&m.rowp),
            nrows: m.nrows,
            ncols: m.ncols,
        }
    }

    /// Declare a loop-carried vector of length `len` (persistent slot;
    /// the `_for` loop state). Must be assigned before it is read.
    pub fn carried(&mut self, len: usize) -> Vect {
        assert!(len > 0, "program: zero-length carried vector");
        self.vals.push(ValInfo {
            len,
            kind: VKind::Carried,
            written: false,
            paired: false,
            staged: Vec::new(),
        });
        Vect(self.vals.len() - 1)
    }

    /// Evaluate `expr` into a fresh temporary of length `len`. The
    /// buffer planner recycles temporary slots by liveness.
    pub fn compute(&mut self, len: usize, expr: PExpr) -> Vect {
        assert!(len > 0, "program: zero-length temporary");
        self.vals.push(ValInfo {
            len,
            kind: VKind::Temp,
            written: true,
            paired: false,
            staged: Vec::new(),
        });
        let dst = Vect(self.vals.len() - 1);
        self.check_expr(&expr, len, Some(dst), false);
        self.push_stmt(Stmt::Emit { dst, off: 0, len, expr, staged: false });
        dst
    }

    /// Overwrite a carried vector with `expr`. If the expression reads
    /// `dst` itself (through any view), the write is automatically
    /// staged into the back buffer and committed — `dst` becomes
    /// double-buffered.
    pub fn assign(&mut self, dst: Vect, expr: PExpr) {
        let len = self.writable(dst);
        let self_read = self.reads_val(&expr.0, dst);
        self.check_expr(&expr, len, if self_read { None } else { Some(dst) }, false);
        self.vals[dst.0].written = true;
        if self_read {
            self.mark_staged(dst, 0, len);
            self.push_stmt(Stmt::Emit { dst, off: 0, len, expr, staged: true });
            self.commit(dst);
        } else {
            self.push_stmt(Stmt::Emit { dst, off: 0, len, expr, staged: false });
        }
    }

    /// In-place update of a carried vector: `expr` must contain
    /// [`PExpr::acc`] (the destination's current value) on its left
    /// spine and must not read `dst` any other way — element-wise
    /// updates like CG's `x += alpha·p` reuse the slot with no copy.
    pub fn update(&mut self, dst: Vect, expr: PExpr) {
        let len = self.writable(dst);
        assert!(contains_acc(&expr.0), "program: update expression must read acc()");
        assert!(
            !self.reads_val(&expr.0, dst),
            "program: update may read the destination only through acc() \
             (views of the destination need stage_region/commit)"
        );
        assert!(self.vals[dst.0].written, "program: update of unwritten vector");
        self.check_expr(&expr, len, None, true);
        self.push_stmt(Stmt::Emit { dst, off: 0, len, expr, staged: false });
    }

    /// Stage a region write `dst[off..off+len] = expr` into the back
    /// buffer of `dst` (reads of `dst` — including inside `expr` — still
    /// see the front buffer). The staged regions must tile `dst` exactly
    /// before [`ProgramBuilder::commit`] flips the pair. This is the
    /// FFT's `cat(up, down)` replacement: two region writes into the
    /// back buffer instead of a materialising concat.
    pub fn stage_region(&mut self, dst: Vect, off: usize, len: usize, expr: PExpr) {
        let total = self.writable(dst);
        assert!(len > 0 && off + len <= total, "program: staged region out of range");
        assert!(
            self.vals[dst.0].written,
            "program: staging into an unwritten vector (assign it first)"
        );
        self.check_expr(&expr, len, None, false);
        self.mark_staged(dst, off, len);
        self.push_stmt(Stmt::Emit { dst, off, len, expr, staged: true });
    }

    /// Commit the staged regions of `dst`: validates they tile the
    /// vector exactly, then flips the front/back pair (O(1), no copy).
    pub fn commit(&mut self, dst: Vect) {
        let len = self.vals[dst.0].len;
        let mut regions = std::mem::take(&mut self.vals[dst.0].staged);
        assert!(!regions.is_empty(), "program: commit with no staged regions");
        regions.sort_unstable();
        let mut covered = 0usize;
        for (off, l) in &regions {
            assert!(
                *off == covered,
                "program: staged regions must tile the vector exactly \
                 (gap or overlap at offset {covered})"
            );
            covered += l;
        }
        assert_eq!(covered, len, "program: staged regions do not cover the vector");
        self.push_stmt(Stmt::Commit { dst });
    }

    /// Sparse matrix-vector product `dst = A·x` against a baked CSR
    /// matrix, bit-identical to [`crate::sparse::Csr::spmv`]. Returns a
    /// fresh temporary of length `nrows`.
    pub fn spmv(&mut self, a: &BakedCsr, x: impl Into<Rd>) -> Vect {
        let x = x.into();
        let xlen = self.rd_len(x);
        assert_eq!(xlen, a.ncols, "program: spmv input length != matrix columns");
        self.assert_readable(x);
        self.vals.push(ValInfo {
            len: a.nrows,
            kind: VKind::Temp,
            written: true,
            paired: false,
            staged: Vec::new(),
        });
        let dst = Vect(self.vals.len() - 1);
        self.push_stmt(Stmt::Spmv { dst, csr: *a, x });
        dst
    }

    /// Dot product into a fresh scalar register, computed with
    /// [`crate::kernels::blas1::dot`]'s exact association so captured CG
    /// reductions match the host driver bit-for-bit.
    pub fn dot(&mut self, a: impl Into<Rd>, b: impl Into<Rd>) -> Sval {
        let (a, b) = (a.into(), b.into());
        assert_eq!(self.rd_len(a), self.rd_len(b), "program: dot length mismatch");
        self.assert_readable(a);
        self.assert_readable(b);
        let dst = Sval(self.n_sregs);
        self.n_sregs += 1;
        self.push_stmt(Stmt::Dot { dst, a, b });
        dst
    }

    /// Scalar register arithmetic into a fresh register (CG's
    /// `alpha = r2 / pAp`).
    pub fn sbin(&mut self, op: BinOp, a: Sval, b: Sval) -> Sval {
        assert!(a.0 < self.n_sregs && b.0 < self.n_sregs);
        let dst = Sval(self.n_sregs);
        self.n_sregs += 1;
        self.push_stmt(Stmt::SBin { op, dst, a, b });
        dst
    }

    /// Copy a scalar register (rebinding a carried scalar at loop-body
    /// end, e.g. CG's `r2 = r2_new`).
    pub fn set_scalar(&mut self, dst: Sval, src: Sval) {
        assert!(dst.0 < self.n_sregs && src.0 < self.n_sregs);
        self.push_stmt(Stmt::SSet { dst, src });
    }

    /// `_for` with a uniform body: `body` is captured **once** and the
    /// recorded steps replay `trip` times per invocation (the CG
    /// iteration loop). The trip count is resolved at capture.
    pub fn repeat(&mut self, trip: usize, body: impl FnOnce(&mut ProgramBuilder)) {
        self.frames.push(Vec::new());
        body(self);
        let nodes = self.frames.pop().expect("balanced loop frames");
        self.push_node(PNode::For { trip, uniform: true, bodies: vec![nodes] });
    }

    /// `_for` whose body geometry depends on the iteration index (the
    /// FFT's stage loop: twiddle section lengths halve per stage):
    /// `body` is captured once per iteration, and the per-iteration step
    /// lists are recorded under one structured loop node.
    pub fn for_each(&mut self, trip: usize, mut body: impl FnMut(&mut ProgramBuilder, usize)) {
        let mut bodies = Vec::with_capacity(trip);
        for k in 0..trip {
            self.frames.push(Vec::new());
            body(self, k);
            bodies.push(self.frames.pop().expect("balanced loop frames"));
        }
        self.push_node(PNode::For { trip, uniform: false, bodies });
    }

    /// Append a value to the invocation output (outputs are
    /// concatenated in declaration order).
    pub fn output(&mut self, v: impl Into<Rd>) {
        let v = v.into();
        self.assert_readable(v);
        self.outputs.push(v);
    }

    /// Freeze the capture: run the buffer planner, compile every
    /// statement's expression to a tape, and produce the replayable
    /// [`Program`].
    pub fn finish(self) -> crate::Result<Program> {
        if self.outputs.is_empty() {
            return Err(crate::Error::Invalid("program: no outputs declared".into()));
        }
        for v in &self.vals {
            if !v.staged.is_empty() {
                return Err(crate::Error::Invalid(
                    "program: staged regions never committed".into(),
                ));
            }
        }
        let bp = plan::plan_buffers(&self.vals, &self.root, &self.stmts, &self.outputs);
        let mut steps = Vec::with_capacity(self.stmts.len());
        for stmt in &self.stmts {
            steps.push(self.compile_stmt(stmt, &bp)?);
        }
        let structure = map_nodes(&self.root);
        let outputs: Vec<PBind> = self.outputs.iter().map(|r| self.bind_rd(*r, &bp)).collect();
        let out_len = self.outputs.iter().map(|r| self.rd_len(*r)).sum();
        Ok(Program::new(
            self.param_lens,
            self.baked_f,
            self.baked_i,
            steps,
            structure,
            bp.slot_lens,
            bp.pairs,
            self.n_sregs,
            outputs,
            out_len,
        ))
    }

    // -- capture-time validation helpers ------------------------------

    fn push_stmt(&mut self, s: Stmt) {
        self.stmts.push(s);
        let id = self.stmts.len() - 1;
        self.push_node(PNode::Stmt(id));
    }

    fn push_node(&mut self, n: PNode) {
        match self.frames.last_mut() {
            Some(f) => f.push(n),
            None => self.root.push(n),
        }
    }

    fn writable(&mut self, dst: Vect) -> usize {
        let v = &self.vals[dst.0];
        assert!(
            !matches!(v.kind, VKind::Param(_)),
            "program: parameters are read-only"
        );
        v.len
    }

    fn mark_staged(&mut self, dst: Vect, off: usize, len: usize) {
        assert!(
            self.vals[dst.0].kind == VKind::Carried,
            "program: only carried vectors can be double-buffered"
        );
        self.vals[dst.0].paired = true;
        self.vals[dst.0].staged.push((off, len));
    }

    fn rd_len(&self, r: Rd) -> usize {
        match r {
            Rd::Val(v) => self.vals[v.0].len,
            Rd::Baked(b) => self.baked_f[b.0].len(),
        }
    }

    fn assert_readable(&self, r: Rd) {
        if let Rd::Val(v) = r {
            assert!(self.vals[v.0].written, "program: read of unwritten vector");
        }
    }

    fn reads_val(&self, e: &PE, v: Vect) -> bool {
        match e {
            PE::Read { src: Rd::Val(s), .. } | PE::Gather { src: Rd::Val(s), .. } => s.0 == v.0,
            PE::Bin(_, a, b) => self.reads_val(a, v) || self.reads_val(b, v),
            PE::Un(_, a) => self.reads_val(a, v),
            _ => false,
        }
    }

    /// Validate an expression against the statement's output length:
    /// every leaf read must be in range, sources must be written, and
    /// `no_read` (the destination of a non-staged write) must not be
    /// read at all.
    fn check_expr(&self, e: &PExpr, out_len: usize, no_read: Option<Vect>, allow_acc: bool) {
        self.check_pe(&e.0, out_len, no_read, allow_acc);
    }

    fn check_pe(&self, e: &PE, out_len: usize, no_read: Option<Vect>, allow_acc: bool) {
        match e {
            PE::Read { src, view } => {
                self.assert_readable(*src);
                if let (Some(d), Rd::Val(s)) = (no_read, src) {
                    assert!(
                        s.0 != d.0,
                        "program: expression reads its own destination; use \
                         stage_region/commit (views) or update/acc (element-wise)"
                    );
                }
                let src_len = self.rd_len(*src);
                if let PView::Tile { period } = view {
                    assert!(
                        *period > 0 && *period <= src_len,
                        "program: tile period out of range"
                    );
                }
                assert!(
                    view.max_src_index(out_len) < src_len,
                    "program: view reads past the end of its source"
                );
            }
            PE::Gather { src, idx } => {
                self.assert_readable(*src);
                if let (Some(d), Rd::Val(s)) = (no_read, src) {
                    assert!(s.0 != d.0, "program: gather reads its own destination");
                }
                let table = &self.baked_i[idx.0];
                assert!(
                    table.len() >= out_len,
                    "program: gather index table shorter than the output region"
                );
                let src_len = self.rd_len(*src);
                assert!(
                    table[..out_len].iter().all(|&i| i >= 0 && (i as usize) < src_len),
                    "program: gather index out of range"
                );
            }
            PE::Splat(s) => assert!(s.0 < self.n_sregs, "program: unknown scalar register"),
            PE::Const(_) => {}
            PE::Acc => assert!(
                allow_acc,
                "program: acc() is only valid on the left spine of an update() expression"
            ),
            PE::Bin(_, a, b) => {
                self.check_pe(a, out_len, no_read, allow_acc);
                self.check_pe(b, out_len, no_read, false);
            }
            PE::Un(_, a) => self.check_pe(a, out_len, no_read, allow_acc),
        }
    }

    // -- statement compilation ----------------------------------------

    fn bind_rd(&self, r: Rd, bp: &plan::BufferPlan) -> PBind {
        match r {
            Rd::Val(v) => match self.vals[v.0].kind {
                VKind::Param(p) => PBind::Param(p),
                _ => match bp.storage[v.0] {
                    plan::Storage::Single(s) => PBind::Slot(s),
                    plan::Storage::Pair(p) => PBind::Front(p),
                    plan::Storage::None => unreachable!("non-param value without storage"),
                },
            },
            Rd::Baked(b) => PBind::Baked(b.0),
        }
    }

    fn dst_of(&self, dst: Vect, staged: bool, bp: &plan::BufferPlan) -> PDst {
        match bp.storage[dst.0] {
            plan::Storage::Single(s) => {
                debug_assert!(!staged);
                PDst::Slot(s)
            }
            plan::Storage::Pair(p) => {
                if staged {
                    PDst::Back(p)
                } else {
                    PDst::Front(p)
                }
            }
            plan::Storage::None => unreachable!("write to a parameter"),
        }
    }

    fn lower_pe(
        &self,
        e: &PE,
        out_len: usize,
        bp: &plan::BufferPlan,
        binds: &mut Vec<PBind>,
        ibinds: &mut Vec<usize>,
    ) -> KTree {
        match e {
            PE::Read { src, view } => {
                binds.push(self.bind_rd(*src, bp));
                KTree::Leaf { leaf: (binds.len() - 1) as u16, view: view.to_view(out_len) }
            }
            PE::Gather { src, idx } => {
                binds.push(self.bind_rd(*src, bp));
                let leaf = (binds.len() - 1) as u16;
                ibinds.push(idx.0);
                KTree::Gather { src: leaf, idx: (ibinds.len() - 1) as u16, base: 0 }
            }
            PE::Splat(s) => {
                binds.push(PBind::Sregs);
                KTree::Splat { leaf: (binds.len() - 1) as u16, idx: s.0 }
            }
            PE::Const(c) => KTree::Const(*c),
            PE::Acc => KTree::Acc,
            PE::Bin(op, a, b) => KTree::Bin(
                *op,
                Box::new(self.lower_pe(a, out_len, bp, binds, ibinds)),
                Box::new(self.lower_pe(b, out_len, bp, binds, ibinds)),
            ),
            PE::Un(op, a) => {
                KTree::Un(*op, Box::new(self.lower_pe(a, out_len, bp, binds, ibinds)))
            }
        }
    }

    fn compile_stmt(&self, stmt: &Stmt, bp: &plan::BufferPlan) -> crate::Result<CStep> {
        Ok(match stmt {
            Stmt::Emit { dst, off, len, expr, staged } => {
                let mut binds = Vec::new();
                let mut ibinds = Vec::new();
                let kt = self.lower_pe(&expr.0, *len, bp, &mut binds, &mut ibinds);
                CStep::Emit(EmitStep::new(
                    self.dst_of(*dst, *staged, bp),
                    *off,
                    *len,
                    super::engine::eval::TapeProgram::compile_with(&kt, self.backend)?,
                    binds,
                    ibinds,
                ))
            }
            Stmt::Commit { dst } => match bp.storage[dst.0] {
                plan::Storage::Pair(p) => CStep::Flip { pair: p },
                _ => unreachable!("commit of an unpaired vector"),
            },
            Stmt::Spmv { dst, csr, x } => CStep::Spmv {
                dst: self.dst_of(*dst, false, bp),
                vals: csr.vals.0,
                indx: csr.indx.0,
                rowp: csr.rowp.0,
                x: self.bind_rd(*x, bp),
                rows: csr.nrows,
            },
            Stmt::Dot { dst, a, b } => CStep::Dot {
                dst: dst.0,
                a: self.bind_rd(*a, bp),
                b: self.bind_rd(*b, bp),
            },
            Stmt::SBin { op, dst, a, b } => {
                CStep::SBin { op: *op, dst: dst.0, a: a.0, b: b.0 }
            }
            Stmt::SSet { dst, src } => CStep::SSet { dst: dst.0, src: src.0 },
        })
    }
}

fn contains_acc(e: &PE) -> bool {
    match e {
        PE::Acc => true,
        PE::Bin(_, a, b) => contains_acc(a) || contains_acc(b),
        PE::Un(_, a) => contains_acc(a),
        _ => false,
    }
}

/// Map the capture IR's structure tree onto compiled step indices
/// (statements and steps are 1:1 and in the same order).
fn map_nodes(nodes: &[PNode]) -> Vec<CNode> {
    nodes
        .iter()
        .map(|n| match n {
            PNode::Stmt(i) => CNode::Step(*i),
            PNode::For { trip, uniform, bodies } => CNode::For {
                trip: *trip,
                uniform: *uniform,
                bodies: bodies.iter().map(|b| map_nodes(b)).collect(),
            },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn carried_copy_and_uniform_loop() {
        // acc = x; repeat 3 { acc *= 3 } => x * 3^3
        let mut pb = ProgramBuilder::new();
        let x = pb.param(5);
        let acc = pb.carried(5);
        pb.assign(acc, PExpr::read(x));
        pb.repeat(3, |pb| {
            pb.update(acc, PExpr::acc() * PExpr::lit(3.0));
        });
        pb.output(acc);
        let prog = pb.finish().unwrap();
        assert_eq!(prog.loop_trips(), vec![3]);
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let out = prog.invoke(&[&xs]).unwrap();
        let want: Vec<f64> = xs.iter().map(|v| v * 27.0).collect();
        assert_eq!(out, want);
        // replays recycle one state
        let _ = prog.invoke(&[&xs]).unwrap();
        assert_eq!(prog.stats().states_created, 1);
        assert_eq!(prog.stats().replays, 2);
    }

    #[test]
    fn double_buffered_reverse_swap() {
        // d = x; for_each stage: d = [second half | first half] staged —
        // exercises front/back pairing and region commits.
        let n = 8;
        let mut pb = ProgramBuilder::new();
        let x = pb.param(n);
        let d = pb.carried(n);
        pb.assign(d, PExpr::read(x));
        pb.for_each(3, |pb, _| {
            pb.stage_region(d, 0, n / 2, PExpr::sec(d, n / 2, 1));
            pb.stage_region(d, n / 2, n / 2, PExpr::sec(d, 0, 1));
            pb.commit(d);
        });
        pb.output(d);
        let prog = pb.finish().unwrap();
        assert_eq!(prog.n_pairs(), 1);
        assert_eq!(prog.n_slots(), 2, "double buffering = exactly two slots");
        let xs: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let out = prog.invoke(&[&xs]).unwrap();
        // three half-swaps = one net half-swap
        let want = vec![4.0, 5.0, 6.0, 7.0, 0.0, 1.0, 2.0, 3.0];
        assert_eq!(out, want);
    }

    #[test]
    fn assign_with_self_read_auto_stages() {
        let n = 4;
        let mut pb = ProgramBuilder::new();
        let x = pb.param(n);
        let d = pb.carried(n);
        pb.assign(d, PExpr::read(x));
        // d = reverse-ish via strided self read: auto double-buffered.
        pb.assign(d, PExpr::sec(d, 0, 1) + PExpr::sec(d, 0, 1));
        pb.output(d);
        let prog = pb.finish().unwrap();
        assert_eq!(prog.n_pairs(), 1);
        let out = prog.invoke(&[&[1.0, 2.0, 3.0, 4.0]]).unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn temp_slots_are_recycled() {
        // Two disjoint-liveness temps must share one slot.
        let n = 6;
        let mut pb = ProgramBuilder::new();
        let x = pb.param(n);
        let c = pb.carried(n);
        let t1 = pb.compute(n, PExpr::read(x) * PExpr::lit(2.0));
        pb.assign(c, PExpr::read(t1)); // t1 dies here
        let t2 = pb.compute(n, PExpr::read(c) + PExpr::lit(1.0));
        pb.assign(c, PExpr::read(t2));
        pb.output(c);
        let prog = pb.finish().unwrap();
        // c (1 slot) + one shared temp slot
        assert_eq!(prog.n_slots(), 2, "temps with disjoint liveness share a slot");
        let out = prog.invoke(&[&[1.0; 6]]).unwrap();
        assert_eq!(out, vec![3.0; 6]);
    }

    #[test]
    fn scalars_dot_and_sbin() {
        let mut pb = ProgramBuilder::new();
        let x = pb.param(4);
        let c = pb.carried(4);
        pb.assign(c, PExpr::read(x));
        let d = pb.dot(c, c); // Σ x²
        let e = pb.sbin(BinOp::Div, d, d); // 1.0
        pb.update(c, PExpr::acc() * PExpr::splat(e) + PExpr::splat(d));
        pb.output(c);
        let prog = pb.finish().unwrap();
        let out = prog.invoke(&[&[1.0, 2.0, 3.0, 4.0]]).unwrap();
        let s = 1.0 + 4.0 + 9.0 + 16.0;
        assert_eq!(out, vec![1.0 + s, 2.0 + s, 3.0 + s, 4.0 + s]);
    }

    #[test]
    fn gather_and_tile_views() {
        let mut pb = ProgramBuilder::new();
        let x = pb.param(4);
        let idx = pb.bake_i64(&[3, 2, 1, 0]);
        let tw = pb.bake(&[10.0, 20.0]);
        let c = pb.carried(4);
        pb.assign(c, PExpr::gather(x, idx) * PExpr::tile(tw, 2));
        pb.output(c);
        let prog = pb.finish().unwrap();
        let out = prog.invoke(&[&[1.0, 2.0, 3.0, 4.0]]).unwrap();
        assert_eq!(out, vec![40.0, 60.0, 20.0, 10.0]);
    }

    #[test]
    fn spmv_matches_csr() {
        let dense = [1.0, 0.0, 2.0, 0.0, 3.0, 0.0, 4.0, 5.0, 6.0];
        let m = Csr::from_dense(&dense, 3, 3);
        let mut pb = ProgramBuilder::new();
        let x = pb.param(3);
        let a = pb.bake_csr(&m);
        let y = pb.spmv(&a, x);
        pb.output(y);
        let prog = pb.finish().unwrap();
        let xs = [1.0, 10.0, 100.0];
        let out = prog.invoke(&[&xs]).unwrap();
        let want = m.spmv_alloc(&xs);
        assert_eq!(out, want);
    }

    #[test]
    fn argument_mismatch_is_error() {
        let mut pb = ProgramBuilder::new();
        let x = pb.param(4);
        let c = pb.carried(4);
        pb.assign(c, PExpr::read(x));
        pb.output(c);
        let prog = pb.finish().unwrap();
        assert!(prog.invoke(&[&[1.0; 3]]).is_err(), "length mismatch");
        assert!(prog.invoke(&[]).is_err(), "arity mismatch");
    }

    #[test]
    fn dangling_stage_is_error() {
        let mut pb = ProgramBuilder::new();
        let x = pb.param(4);
        let c = pb.carried(4);
        pb.assign(c, PExpr::read(x));
        pb.stage_region(c, 0, 4, PExpr::sec(c, 0, 1));
        pb.output(c);
        assert!(pb.finish().is_err(), "uncommitted staged regions must fail finish");
    }

    #[test]
    #[should_panic(expected = "only through acc()")]
    fn update_self_view_read_panics() {
        let mut pb = ProgramBuilder::new();
        let x = pb.param(4);
        let c = pb.carried(4);
        pb.assign(c, PExpr::read(x));
        // a viewed self-read needs stage_region/commit, not update
        pb.update(c, PExpr::acc() + PExpr::sec(c, 0, 1));
    }

    #[test]
    #[should_panic(expected = "staged regions must tile")]
    fn partial_commit_panics() {
        let mut pb = ProgramBuilder::new();
        let x = pb.param(4);
        let c = pb.carried(4);
        pb.assign(c, PExpr::read(x));
        pb.stage_region(c, 2, 2, PExpr::sec(c, 0, 1));
        pb.commit(c);
    }
}
