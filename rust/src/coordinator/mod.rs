//! The ArBB-like data-parallel runtime — the system the paper evaluates.
//!
//! Layer 3 of the reproduction: a rust embedded DSL with dense containers,
//! element-wise / reduction / permutation operators and serial-semantics
//! control flow, backed by a capture → optimise → plan → execute pipeline
//! ("the JIT") and pluggable engines:
//!
//! * `O2` — vectorised serial execution (the paper's single-core runs);
//! * `O3` — fork-join threaded execution over `num_workers` workers
//!   (the paper's `ARBB_NUM_CORES`);
//! * a recording mode feeding the calibrated virtual-time scaling
//!   simulator ([`engine::sim`]) that stands in for the 40-core node.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath; the same snippet is
//! // exercised by unit tests and examples/quickstart.rs)
//! use arbb_rs::coordinator::Context;
//!
//! let ctx = Context::new();
//! let a = ctx.bind1(&[1.0, 2.0, 3.0, 4.0]);
//! let b = ctx.bind1(&[10.0, 20.0, 30.0, 40.0]);
//! let c = (&a + &b).scale(0.5);
//! assert_eq!(c.to_vec(), vec![5.5, 11.0, 16.5, 22.0]);
//! ```

pub mod api;
pub mod engine;
pub mod map;
pub mod node;
pub mod ops;
pub mod passes;
pub mod plan;
pub mod program;
pub mod shape;

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

pub use api::{CplxV, Mat2, Scal, Vec1, VecI64};
pub use engine::backend::BackendSel;
pub use engine::sim::{MachineModel, SimResult};
pub use engine::{ExecStats, Mode, StepRecord};
pub use shape::{DType, Shape};

use engine::pool::SharedPool;
use engine::EngineCfg;
use node::NodeRef;
use plan::PlanOptions;

/// Optimisation level, mirroring `ARBB_OPT_LEVEL` (§3 of the paper):
/// `O2` vectorises on a single core, `O3` additionally uses multiple
/// cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OptLevel {
    O2,
    O3,
}

/// Engine selection (exposed for diagnostics and the e2e driver).
pub use engine::Mode as Engine;

/// Runtime options — the environment knobs of §3 plus the optimiser
/// toggles the ablation benches sweep.
#[derive(Debug, Clone, Copy)]
pub struct Options {
    /// `ARBB_OPT_LEVEL`: O2 = serial vectorised, O3 = threaded.
    pub opt_level: OptLevel,
    /// `ARBB_NUM_CORES`: worker count for O3.
    pub num_workers: usize,
    /// Element-wise fusion (ArBB's main JIT optimisation).
    pub fusion: bool,
    /// In-place buffer donation for accumulations / structural updates.
    pub in_place: bool,
    /// Structural CSE over each pending region before planning.
    pub cse: bool,
    /// Consolidated lowering parameters (chunk grain and fan-out,
    /// segmented-spmv path, panel sizes) — see
    /// [`engine::tuning::Tuning`]. The plan explorer varies these per
    /// (kernel, shape, backend); defaults reproduce the historical
    /// hard-coded behaviour.
    pub tuning: engine::tuning::Tuning,
    /// Record per-chunk timings for the scaling simulator.
    pub record: bool,
    /// Kernel backend selection (the vector half of the paper's
    /// "thread-level and vector-level parallelism"): `Auto` honours the
    /// `PALLAS_BACKEND` environment override, else takes the best
    /// detected ISA. Both `O2` and `O3` vectorise — the paper's levels
    /// differ in threading, not SIMD.
    pub backend: BackendSel,
}

impl Default for Options {
    fn default() -> Self {
        Options {
            opt_level: OptLevel::O2,
            num_workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            fusion: true,
            in_place: true,
            cse: false,
            tuning: engine::tuning::Tuning::default(),
            record: false,
            backend: BackendSel::Auto,
        }
    }
}

struct CtxInner {
    opts: RefCell<Options>,
    /// Handle into the process-wide shared worker pool (O3 only). All
    /// contexts with the same worker count share one set of long-lived
    /// threads — per-dispatch pool spawn/join is gone.
    pool: RefCell<Option<Arc<SharedPool>>>,
    stats: RefCell<ExecStats>,
}

/// An ArBB-style execution context: owns the options, the worker pool and
/// the execution statistics. Cheap to clone (shared handle).
#[derive(Clone)]
pub struct Context {
    inner: Rc<CtxInner>,
}

impl Default for Context {
    fn default() -> Self {
        Self::new()
    }
}

impl Context {
    /// Context with default options (serial `O2`).
    pub fn new() -> Self {
        Self::with_options(Options::default())
    }

    /// Context with explicit options.
    pub fn with_options(opts: Options) -> Self {
        Context {
            inner: Rc::new(CtxInner {
                opts: RefCell::new(opts),
                pool: RefCell::new(None),
                stats: RefCell::new(ExecStats::default()),
            }),
        }
    }

    /// Serial context (O2) — the paper's single-core configuration.
    pub fn serial() -> Self {
        Self::with_options(Options { opt_level: OptLevel::O2, ..Default::default() })
    }

    /// Threaded context (O3) with `workers` workers.
    pub fn parallel(workers: usize) -> Self {
        Self::with_options(Options {
            opt_level: OptLevel::O3,
            num_workers: workers.max(1),
            ..Default::default()
        })
    }

    /// Recording context: serial execution + per-chunk timings for the
    /// scaling simulator.
    pub fn recording() -> Self {
        Self::with_options(Options { record: true, ..Default::default() })
    }

    pub fn options(&self) -> Options {
        *self.inner.opts.borrow()
    }

    pub fn set_options(&self, opts: Options) {
        // Worker-count or level changes invalidate the pool.
        *self.inner.pool.borrow_mut() = None;
        *self.inner.opts.borrow_mut() = opts;
    }

    pub fn set_num_workers(&self, n: usize) {
        let mut o = self.options();
        o.num_workers = n.max(1);
        self.set_options(o);
    }

    pub fn set_fusion(&self, on: bool) {
        let mut o = self.options();
        o.fusion = on;
        self.set_options(o);
    }

    /// Select the kernel backend for this context's engine.
    pub fn set_backend(&self, sel: BackendSel) {
        let mut o = self.options();
        o.backend = sel;
        self.set_options(o);
    }

    /// Name of the kernel backend this context's engine resolves to.
    pub fn backend_name(&self) -> &'static str {
        engine::backend::select(self.options().backend).name()
    }

    /// Execution statistics accumulated since the last [`Self::reset_stats`].
    pub fn stats<R>(&self, f: impl FnOnce(&ExecStats) -> R) -> R {
        f(&self.inner.stats.borrow())
    }

    pub fn reset_stats(&self) {
        self.inner.stats.borrow_mut().clear();
    }

    /// Take the recorded step log (for the scaling simulator).
    pub fn take_records(&self) -> (Vec<StepRecord>, u64) {
        let mut st = self.inner.stats.borrow_mut();
        let recs = std::mem::take(&mut st.records);
        let forces = st.forces;
        (recs, forces)
    }

    /// Force materialisation of `node` (the ArBB `call()` + sync
    /// boundary). No-op when already materialised.
    ///
    /// Engine errors at this host-API boundary are programming errors
    /// (malformed plans) and panic; the serving path ([`crate::serve`])
    /// uses fallible execution end to end instead.
    pub(crate) fn force(&self, node: &NodeRef) {
        if let Err(e) = self.try_force(node) {
            panic!("arbb: execution failed: {e}");
        }
    }

    /// Fallible variant of [`Self::force`].
    pub(crate) fn try_force(&self, node: &NodeRef) -> crate::Result<()> {
        if node.is_materialized() {
            return Ok(());
        }
        let opts = self.options();
        let t0 = Instant::now();
        if opts.cse {
            passes::cse::cse(node);
        }
        let p = plan::plan(node, PlanOptions { fusion: opts.fusion, in_place: opts.in_place });
        let plan_secs = t0.elapsed().as_secs_f64();

        let cfg = EngineCfg {
            mode: match opts.opt_level {
                OptLevel::O2 => Mode::Serial,
                OptLevel::O3 => Mode::Parallel,
            },
            record: opts.record,
            in_place: opts.in_place,
            backend: engine::backend::select(opts.backend),
            tuning: opts.tuning,
        };
        // Attach to the shared pool for O3 (interned per worker count;
        // threads persist across dispatches and across contexts).
        if cfg.mode == Mode::Parallel && self.inner.pool.borrow().is_none() {
            *self.inner.pool.borrow_mut() = Some(engine::pool::shared(opts.num_workers));
        }
        let pool = self.inner.pool.borrow().clone();
        let mut stats = self.inner.stats.borrow_mut();
        stats.forces += 1;
        stats.plan_secs += plan_secs;
        engine::execute_plan(&p, &cfg, pool.as_deref(), &mut stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_roundtrip() {
        let ctx = Context::new();
        let a = ctx.bind1(&[1.0, 2.0, 3.0]);
        assert_eq!(a.to_vec(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn stats_count_forces() {
        let ctx = Context::new();
        let a = ctx.bind1(&[1.0, 2.0]);
        let b = (&a + &a).to_vec();
        assert_eq!(b, vec![2.0, 4.0]);
        assert_eq!(ctx.stats(|s| s.forces), 1);
        ctx.reset_stats();
        assert_eq!(ctx.stats(|s| s.forces), 0);
    }

    #[test]
    fn parallel_context_matches_serial() {
        let xs: Vec<f64> = (0..10_000).map(|i| i as f64 * 0.25).collect();
        let serial = {
            let ctx = Context::serial();
            let a = ctx.bind1(&xs);
            ((&a * &a) + &a).to_vec()
        };
        let par = {
            let ctx = Context::parallel(4);
            // Small grain to force multiple chunks even at this size.
            let mut o = ctx.options();
            o.tuning.grain = 256;
            ctx.set_options(o);
            let a = ctx.bind1(&xs);
            ((&a * &a) + &a).to_vec()
        };
        assert_eq!(serial, par);
    }
}
