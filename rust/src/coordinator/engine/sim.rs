//! Virtual-time scaling simulator.
//!
//! The paper's scaling figures (Fig 1c/d, 2c/d, 5b, 7b) sweep
//! `ARBB_NUM_CORES` / `OMP_NUM_THREADS` from 1 to 40 on a Westmere-EX
//! node. This testbed has a single core, so scaling curves are produced by
//! a calibrated analytic replay: the engine executes the *real* chunk
//! schedule serially and records per-chunk wall time plus per-step
//! flop/byte estimates; the model below then computes the step's parallel
//! makespan under `P` workers, bounded by a bandwidth-saturation roofline
//! and charged fork-join + dispatch overheads.
//!
//! What this preserves from the paper (see DESIGN.md §2): *where* each
//! kernel stops scaling is decided by (a) chunk granularity vs fork-join
//! cost, (b) arithmetic intensity vs the node bandwidth roof, and (c)
//! serial steps (mod2am's `arbb_mxm0` never parallelises; FFT stage
//! barriers dominate at small sizes) — all of which the replay captures.

use super::StepRecord;

/// Calibrated machine model. Absolute scales come from
/// `bench::machine::calibrate()`; node-level ratios default to
/// Westmere-EX-like values (4-socket HX5 blade, §3 of the paper).
#[derive(Debug, Clone)]
pub struct MachineModel {
    /// Modelled cores per node (paper: 40).
    pub cores: usize,
    /// Single-core stream bandwidth (GB/s).
    pub bw_core_gbs: f64,
    /// Node saturation bandwidth (GB/s). WSM-EX 4-socket: roughly 8×
    /// a single core's achievable stream bandwidth.
    pub bw_node_gbs: f64,
    /// Fork-join barrier base cost per parallel step (seconds).
    pub fork_join_s: f64,
    /// Additional barrier cost per participating worker (seconds).
    pub fork_join_per_worker_s: f64,
    /// Runtime dispatch cost per `force()` round-trip (seconds) — the
    /// ArBB `call()`/sync overhead.
    pub dispatch_s: f64,
}

impl Default for MachineModel {
    fn default() -> Self {
        MachineModel {
            cores: 40,
            bw_core_gbs: 6.0,
            bw_node_gbs: 48.0,
            fork_join_s: 4e-6,
            fork_join_per_worker_s: 0.25e-6,
            dispatch_s: 20e-6,
        }
    }
}

/// Result of simulating one recorded execution at thread count `p`.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub p: usize,
    pub total_secs: f64,
    /// Σ serial chunk time (the P=1 work).
    pub work_secs: f64,
    /// Seconds lost to fork-join barriers.
    pub barrier_secs: f64,
    /// Seconds lost to bandwidth saturation (time above pure work/P).
    pub bw_limited_secs: f64,
}

impl MachineModel {
    /// Effective memory bandwidth with `p` active workers (GB/s).
    pub fn bw(&self, p: usize) -> f64 {
        (p as f64 * self.bw_core_gbs).min(self.bw_node_gbs)
    }

    /// Simulate the recorded steps at `p` workers.
    pub fn simulate(&self, records: &[StepRecord], forces: u64, p: usize) -> SimResult {
        let p = p.max(1);
        let mut total = forces as f64 * self.dispatch_s;
        let mut work = 0.0;
        let mut barrier = 0.0;
        let mut bw_lost = 0.0;
        for r in records {
            let ts: f64 = r.chunk_secs.iter().sum();
            work += ts;
            if !r.parallelizable || p == 1 || r.chunk_secs.len() <= 1 {
                total += ts;
                continue;
            }
            // LPT makespan over p workers.
            let mk = lpt_makespan(&r.chunk_secs, p);
            // Bandwidth roofline: the step cannot finish faster than its
            // memory traffic at the p-worker bandwidth. The bytes estimate
            // is clamped so it is consistent with the measured serial time
            // (caches make the true DRAM traffic smaller than the
            // pessimistic per-element estimate).
            let bytes = r.bytes.min(ts * self.bw_core_gbs * 1e9);
            let t_mem = (bytes * 1e-9) / self.bw(p);
            let fj = self.fork_join_s + self.fork_join_per_worker_s * p as f64;
            let t = mk.max(t_mem) + fj;
            barrier += fj;
            if t_mem > mk {
                bw_lost += t_mem - mk;
            }
            total += t;
        }
        SimResult { p, total_secs: total, work_secs: work, barrier_secs: barrier, bw_limited_secs: bw_lost }
    }

    /// Convenience: simulate a thread sweep, returning (p, total_secs).
    pub fn sweep(&self, records: &[StepRecord], forces: u64, ps: &[usize]) -> Vec<SimResult> {
        ps.iter().map(|&p| self.simulate(records, forces, p)).collect()
    }

    /// Scaling model for a *plain parallel loop* (the OpenMP comparators):
    /// one fork-join region around work measured serially as `t1` seconds
    /// moving `bytes` of memory. `T(P) = max(t1/P, bytes/bw(P)) + barrier`.
    pub fn simple_loop(&self, t1: f64, bytes: f64, p: usize) -> f64 {
        let p = p.max(1);
        if p == 1 {
            return t1;
        }
        // consistency clamp: serial execution already ran at bw_core
        let bytes = bytes.min(t1 * self.bw_core_gbs * 1e9);
        let t_mem = (bytes * 1e-9) / self.bw(p);
        (t1 / p as f64).max(t_mem) + self.fork_join_s + self.fork_join_per_worker_s * p as f64
    }
}

/// Longest-processing-time-first greedy makespan (the classic fork-join
/// load-balance bound; matches a work-stealing pool within a few %).
fn lpt_makespan(chunks: &[f64], p: usize) -> f64 {
    if chunks.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = chunks.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut workers = vec![0.0f64; p.min(sorted.len())];
    for c in sorted {
        // assign to least-loaded worker
        let (i, _) = workers
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        workers[i] += c;
    }
    workers.iter().cloned().fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(chunks: Vec<f64>, bytes: f64, par: bool) -> StepRecord {
        StepRecord {
            kind: "fused",
            elems: 0,
            flops: 0.0,
            bytes,
            chunk_secs: chunks,
            parallelizable: par,
        }
    }

    #[test]
    fn lpt_basics() {
        assert_eq!(lpt_makespan(&[], 4), 0.0);
        assert_eq!(lpt_makespan(&[1.0], 4), 1.0);
        // 4 equal chunks over 2 workers → 2 each
        assert!((lpt_makespan(&[1.0; 4], 2) - 2.0).abs() < 1e-12);
        // perfectly balanced despite skew
        assert!((lpt_makespan(&[3.0, 1.0, 1.0, 1.0], 2) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn compute_bound_scales_linearly() {
        let m = MachineModel {
            bw_core_gbs: 1e9, // effectively unbounded bandwidth
            bw_node_gbs: 1e12,
            fork_join_s: 0.0,
            fork_join_per_worker_s: 0.0,
            dispatch_s: 0.0,
            ..Default::default()
        };
        let r = vec![rec(vec![1e-3; 32], 0.0, true)];
        let t1 = m.simulate(&r, 0, 1).total_secs;
        let t8 = m.simulate(&r, 0, 8).total_secs;
        assert!((t1 / t8 - 8.0).abs() < 0.01, "speedup {}", t1 / t8);
    }

    #[test]
    fn bandwidth_roof_limits_scaling() {
        // step moves 1 GB; core bw 1 GB/s, node roof 4 GB/s
        let m = MachineModel {
            bw_core_gbs: 1.0,
            bw_node_gbs: 4.0,
            fork_join_s: 0.0,
            fork_join_per_worker_s: 0.0,
            dispatch_s: 0.0,
            ..Default::default()
        };
        // serial takes 1s (bandwidth bound at 1 core)
        let r = vec![rec(vec![1.0 / 32.0; 32], 1e9, true)];
        let t16 = m.simulate(&r, 0, 16).total_secs;
        // cannot beat 1GB / 4GB/s = 0.25s regardless of 16 workers
        assert!(t16 >= 0.25 - 1e-9, "t16={t16}");
        let t2 = m.simulate(&r, 0, 2).total_secs;
        assert!(t2 >= 0.5 - 1e-9);
    }

    #[test]
    fn serial_steps_do_not_scale() {
        let m = MachineModel::default();
        let r = vec![rec(vec![1e-3; 8], 0.0, false)];
        let t1 = m.simulate(&r, 0, 1).total_secs;
        let t8 = m.simulate(&r, 0, 8).total_secs;
        assert!((t1 - t8).abs() < 1e-12);
    }

    #[test]
    fn barrier_overhead_grows_with_p() {
        let m = MachineModel::default();
        // tiny chunks: barrier dominates at high P
        let r: Vec<StepRecord> = (0..100).map(|_| rec(vec![1e-7; 4], 0.0, true)).collect();
        let t2 = m.simulate(&r, 0, 2).total_secs;
        let t40 = m.simulate(&r, 0, 40).total_secs;
        assert!(t40 > t2, "overhead should grow: t2={t2} t40={t40}");
    }

    #[test]
    fn dispatch_charged_per_force() {
        let m = MachineModel::default();
        let t = m.simulate(&[], 1000, 1).total_secs;
        assert!((t - 1000.0 * m.dispatch_s).abs() < 1e-12);
    }
}
