//! Execution engines: run a [`Plan`] step by step.
//!
//! Three modes, mirroring the paper's knobs:
//!  * **Serial** (`ARBB_OPT_LEVEL=O2`): vectorised single-core execution.
//!  * **Parallel** (`ARBB_OPT_LEVEL=O3` + `ARBB_NUM_CORES=P`): each step's
//!    chunks are distributed over a fork-join worker pool with a barrier
//!    between steps (ArBB uses pthreads/TBB the same way).
//!  * **Recording**: serial execution that also measures per-chunk cost,
//!    feeding the [`sim`] virtual-time model that reproduces the paper's
//!    40-core scaling figures on this 1-core testbed (see DESIGN.md §2).

pub mod backend;
pub mod cost;
pub mod eval;
pub mod pool;
pub mod program;
pub mod sim;
pub mod tuning;

use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use self::eval::{Tape, BLOCK};
use self::pool::SharedPool;
use super::map::MapArgs;
use super::node::{Data, NodeRef, Op};
use super::ops::RedOp;
use super::plan::{Plan, Step};

/// Execution mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Serial,
    Parallel,
}

/// Engine configuration (derived from [`super::Options`]).
#[derive(Debug, Clone, Copy)]
pub struct EngineCfg {
    pub mode: Mode,
    /// Record per-chunk timings for the scaling simulator.
    pub record: bool,
    /// Allow in-place buffer donation.
    pub in_place: bool,
    /// Kernel backend every step's tape compiles against (resolved from
    /// [`super::Options::backend`]; all backends are bit-identical by
    /// contract, see [`backend`]).
    pub backend: &'static dyn backend::Backend,
    /// Every runtime-tunable lowering parameter (grain, chunk fan-out,
    /// segmented path, panel sizes), consolidated in [`tuning`] so the
    /// plan explorer varies them in one place.
    pub tuning: tuning::Tuning,
}

impl Default for EngineCfg {
    fn default() -> Self {
        EngineCfg {
            mode: Mode::Serial,
            record: false,
            in_place: true,
            backend: backend::active(),
            tuning: tuning::Tuning::default(),
        }
    }
}

/// Per-step record for the scaling simulator.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub kind: &'static str,
    pub elems: usize,
    /// Estimated arithmetic work of the step.
    pub flops: f64,
    /// Estimated bytes moved to/from memory.
    pub bytes: f64,
    /// Measured wall seconds per chunk (serial recording run).
    pub chunk_secs: Vec<f64>,
    /// Whether the step's chunks may execute concurrently.
    pub parallelizable: bool,
}

/// Aggregate execution statistics of a [`super::Context`].
#[derive(Debug, Default)]
pub struct ExecStats {
    /// Number of `force()` round-trips (≈ ArBB `call()` dispatches).
    pub forces: u64,
    pub steps: u64,
    pub elements: u64,
    pub flops: f64,
    pub bytes: f64,
    /// Wall time spent inside the engine.
    pub exec_secs: f64,
    /// Wall time spent planning (capture → IR → plan).
    pub plan_secs: f64,
    /// Step records (only when recording).
    pub records: Vec<StepRecord>,
}

impl ExecStats {
    pub fn clear(&mut self) {
        *self = ExecStats::default();
    }
}

/// Execute a plan. Steps run in order; each step materialises its node.
///
/// Malformed plans (references to nodes no step materialises) surface as
/// [`crate::Error::Invalid`] instead of panicking, so a serving worker
/// can reject the request and keep running.
pub fn execute_plan(
    plan: &Plan,
    cfg: &EngineCfg,
    pool: Option<&SharedPool>,
    stats: &mut ExecStats,
) -> crate::Result<()> {
    let t0 = Instant::now();
    let mut result = Ok(());
    for step in &plan.steps {
        if let Err(e) = exec_step(step, cfg, pool, stats) {
            result = Err(e);
            break;
        }
    }
    stats.exec_secs += t0.elapsed().as_secs_f64();
    result
}

/// A chunk of a step's output index space.
#[derive(Debug, Clone, Copy)]
struct Chunk {
    start: usize,
    len: usize,
}

fn make_chunks(total: usize, cfg: &EngineCfg, workers: usize) -> Vec<Chunk> {
    if total == 0 {
        return vec![];
    }
    // Below the pooled cutoff a sweep is not worth fanning out at all
    // (0 = disabled: the grain floor alone decides, the historical
    // behaviour).
    if total <= cfg.tuning.pooled_cutoff {
        return vec![Chunk { start: 0, len: total }];
    }
    let target = workers * cfg.tuning.chunks_per_worker;
    let mut size = (total + target - 1) / target.max(1);
    if size < cfg.tuning.grain {
        size = cfg.tuning.grain;
    }
    let mut chunks = Vec::with_capacity((total + size - 1) / size);
    let mut s = 0;
    while s < total {
        let l = size.min(total - s);
        chunks.push(Chunk { start: s, len: l });
        s += l;
    }
    chunks
}

/// Wrapper making a raw output pointer shareable across workers writing
/// disjoint ranges.
#[derive(Clone, Copy)]
struct OutPtr(*mut f64);
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

impl OutPtr {
    /// SAFETY: caller guarantees [start, start+len) ranges are disjoint
    /// across concurrent users.
    unsafe fn slice(&self, start: usize, len: usize) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }
}

/// Try to steal `node`'s buffer for in-place mutation; fall back to a copy.
///
/// Eligible when: no user handle or other consumer holds the node
/// (`Rc::strong_count <= 2`: the consumer op edge + the step's own clone),
/// and the buffer `Arc` itself is unique.
///
/// A node with no storage means the plan is malformed (its producing
/// step is missing): [`crate::Error::Invalid`], never a panic.
fn take_or_clone(node: &NodeRef, allow: bool) -> crate::Result<Vec<f64>> {
    let arc = node
        .data()
        .ok_or_else(|| {
            crate::Error::Invalid(format!("malformed plan: node {} not materialised", node.id))
        })?
        .as_f64()
        .clone();
    if allow && Rc::strong_count(node) <= 2 && !node.donated.get() {
        // Drop the storage's own Arc so ours can be unique.
        let taken = node.storage.borrow_mut().take();
        drop(taken);
        match Arc::try_unwrap(arc) {
            Ok(v) => {
                node.donated.set(true);
                return Ok(v);
            }
            Err(arc) => {
                // Restore and copy.
                *node.storage.borrow_mut() = Some(Data::F64(arc.clone()));
                return Ok((*arc).clone());
            }
        }
    }
    Ok((*arc).clone())
}

fn exec_step(
    step: &Step,
    cfg: &EngineCfg,
    pool: Option<&SharedPool>,
    stats: &mut ExecStats,
) -> crate::Result<()> {
    let out_node = step.out().clone();
    let out_len = out_node.shape.len();
    stats.steps += 1;
    stats.elements += out_len as u64;
    let workers = pool.map(|p| p.size()).unwrap_or(1);

    // ---- lower + execute per step kind ----
    let (result, record): (Vec<f64>, Option<StepRecord>) = match step {
        Step::Fused { tree, .. } => {
            let fx = Tape::from_ftree_with(tree, cfg.backend)?;
            let mut out = vec![0.0f64; out_len];
            let chunks = make_chunks(out_len, cfg, workers);
            let fpe = tree.flops_per_elem();
            let bpe = tree.bytes_per_elem() + 8.0;
            let rec = run_elementwise(&fx, &mut out, &chunks, cfg, pool);
            stats.flops += fpe * out_len as f64;
            stats.bytes += bpe * out_len as f64;
            (out, rec.map(|cs| StepRecord {
                kind: step.kind(),
                elems: out_len,
                flops: fpe * out_len as f64,
                bytes: bpe * out_len as f64,
                chunk_secs: cs,
                parallelizable: chunks.len() > 1,
            }))
        }
        Step::Accumulate { base, tree, .. } => {
            let fx = Tape::from_ftree_with(tree, cfg.backend)?;
            let mut out = take_or_clone(base, cfg.in_place)?;
            debug_assert_eq!(out.len(), out_len);
            let chunks = make_chunks(out_len, cfg, workers);
            let fpe = tree.flops_per_elem();
            let bpe = tree.bytes_per_elem() + 8.0; // Acc read counted in tree
            let rec = run_elementwise(&fx, &mut out, &chunks, cfg, pool);
            stats.flops += fpe * out_len as f64;
            stats.bytes += bpe * out_len as f64;
            (out, rec.map(|cs| StepRecord {
                kind: step.kind(),
                elems: out_len,
                flops: fpe * out_len as f64,
                bytes: bpe * out_len as f64,
                chunk_secs: cs,
                parallelizable: chunks.len() > 1,
            }))
        }
        Step::ReduceRows { red, tree, rows, cols, .. } => {
            let fx = Tape::from_ftree_with(tree, cfg.backend)?;
            let mut out = vec![0.0f64; *rows];
            // chunk over output rows
            let row_grain = (cfg.tuning.grain / cols.max(&1)).max(1);
            let chunks = make_row_chunks(*rows, row_grain, cfg, workers);
            let fpe = tree.flops_per_elem() + 1.0;
            let work_elems = rows * cols;
            let rec = run_reduce_rows(&fx, *red, &mut out, *cols, &chunks, cfg, pool);
            stats.flops += fpe * work_elems as f64;
            stats.bytes += (tree.bytes_per_elem()) * work_elems as f64 + 8.0 * *rows as f64;
            (out, rec.map(|cs| StepRecord {
                kind: step.kind(),
                elems: work_elems,
                flops: fpe * work_elems as f64,
                bytes: tree.bytes_per_elem() * work_elems as f64,
                chunk_secs: cs,
                parallelizable: chunks.len() > 1,
            }))
        }
        Step::ReduceCols { red, tree, rows, cols, .. } => {
            let fx = Tape::from_ftree_with(tree, cfg.backend)?;
            let mut out = vec![red.identity(); *cols];
            let col_grain = cfg.tuning.grain.min(*cols).max(1);
            let chunks = make_row_chunks(*cols, col_grain, cfg, workers);
            let fpe = tree.flops_per_elem() + 1.0;
            let work_elems = rows * cols;
            let rec = run_reduce_cols(&fx, *red, &mut out, *rows, *cols, &chunks, cfg, pool);
            stats.flops += fpe * work_elems as f64;
            stats.bytes += tree.bytes_per_elem() * work_elems as f64 + 8.0 * *cols as f64;
            (out, rec.map(|cs| StepRecord {
                kind: step.kind(),
                elems: work_elems,
                flops: fpe * work_elems as f64,
                bytes: tree.bytes_per_elem() * work_elems as f64,
                chunk_secs: cs,
                parallelizable: chunks.len() > 1,
            }))
        }
        Step::ReduceAll { red, tree, len, .. } => {
            let fx = Tape::from_ftree_with(tree, cfg.backend)?;
            let chunks = make_chunks(*len, cfg, workers);
            let fpe = tree.flops_per_elem() + 1.0;
            let (v, rec) = run_reduce_all(&fx, *red, *len, &chunks, cfg, pool);
            stats.flops += fpe * *len as f64;
            stats.bytes += tree.bytes_per_elem() * *len as f64;
            (vec![v], rec.map(|cs| StepRecord {
                kind: step.kind(),
                elems: *len,
                flops: fpe * *len as f64,
                bytes: tree.bytes_per_elem() * *len as f64,
                chunk_secs: cs,
                parallelizable: chunks.len() > 1,
            }))
        }
        Step::SegmentedReduce { red, tree, segp, rows, nnz, runs_hint, .. } => {
            let segp_arc = segp
                .data()
                .ok_or_else(|| {
                    crate::Error::Invalid(
                        "malformed plan: segmented-reduce row pointers not materialised".into(),
                    )
                })?
                .as_i64()
                .clone();
            validate_segp(&segp_arc, *rows, *nnz)?;
            // Compile the operand tree once into a segmented tape; the
            // contiguity hint triggers the one-off run scan (arbb_spmv2).
            let bound =
                eval::BoundSeg::from_ftree_with(tree, *red, &segp_arc, *runs_hint, cfg.backend)?;
            let mut out = vec![0.0f64; *rows];
            // nnz-balanced row panels: equal-row chunks would let one
            // dense row serialise the sweep. Recording runs cut finer
            // panels so the virtual-time simulator can redistribute
            // them over the full 40-thread node model.
            let target = if cfg.record {
                (workers * cfg.tuning.chunks_per_worker).max(40)
            } else {
                workers * cfg.tuning.chunks_per_worker
            };
            let chunks: Vec<Chunk> = crate::sparse::nnz_panels(&segp_arc, target, cfg.tuning.grain)
                .into_iter()
                .map(|(start, len)| Chunk { start, len })
                .collect();
            let fpe = tree.flops_per_elem() + 1.0;
            let bytes = tree.bytes_per_elem() * *nnz as f64 + 16.0 * *rows as f64;
            let optr = OutPtr(out.as_mut_ptr());
            let segp_ref: &[i64] = &segp_arc;
            let body = |c: &Chunk| {
                let o = unsafe { optr.slice(c.start, c.len) };
                eval::with_scratch(|scratch| bound.run_rows(segp_ref, c.start, o, scratch));
            };
            let times = run_chunked(&chunks, cfg, pool, &body);
            stats.flops += fpe * *nnz as f64;
            stats.bytes += bytes;
            let rec = cfg.record.then(|| StepRecord {
                kind: step.kind(),
                elems: *nnz,
                flops: fpe * *nnz as f64,
                bytes,
                chunk_secs: times,
                parallelizable: chunks.len() > 1,
            });
            (out, rec)
        }
        Step::Cat { a, la, b, lb, .. } => {
            let fa = Tape::from_ftree_with(a, cfg.backend)?;
            let fb = Tape::from_ftree_with(b, cfg.backend)?;
            let mut out = vec![0.0f64; la + lb];
            let mut chunk_secs = Vec::new();
            // Two element-wise sub-kernels into disjoint halves.
            {
                let (ha, hb) = out.split_at_mut(*la);
                let ca = make_chunks(*la, cfg, workers);
                let cb = make_chunks(*lb, cfg, workers);
                if let Some(cs) = run_elementwise(&fa, ha, &ca, cfg, pool) {
                    chunk_secs.extend(cs);
                }
                if let Some(cs) = run_elementwise(&fb, hb, &cb, cfg, pool) {
                    chunk_secs.extend(cs);
                }
            }
            let fl = a.flops_per_elem() * *la as f64 + b.flops_per_elem() * *lb as f64;
            let by = (a.bytes_per_elem() + 8.0) * *la as f64 + (b.bytes_per_elem() + 8.0) * *lb as f64;
            stats.flops += fl;
            stats.bytes += by;
            let rec = cfg.record.then(|| StepRecord {
                kind: step.kind(),
                elems: la + lb,
                flops: fl,
                bytes: by,
                chunk_secs,
                parallelizable: la + lb > cfg.tuning.grain,
            });
            (out, rec)
        }
        Step::ReplaceCol { m, col, vtree, .. } => {
            let fx = Tape::from_ftree_with(vtree, cfg.backend)?;
            let (rows, cols) = (out_node.shape.rows(), out_node.shape.cols());
            let mut out = take_or_clone(m, cfg.in_place)?;
            let t0 = Instant::now();
            let mut tmp = vec![0.0f64; rows];
            eval::with_scratch(|scratch| fx.run_range(0, &mut tmp, scratch));
            for r in 0..rows {
                out[r * cols + col] = tmp[r];
            }
            stats.bytes += 16.0 * rows as f64;
            let rec = cfg.record.then(|| StepRecord {
                kind: step.kind(),
                elems: rows,
                flops: vtree.flops_per_elem() * rows as f64,
                bytes: 16.0 * rows as f64,
                chunk_secs: vec![t0.elapsed().as_secs_f64()],
                parallelizable: false,
            });
            (out, rec)
        }
        Step::ReplaceRow { m, row, vtree, .. } => {
            let fx = Tape::from_ftree_with(vtree, cfg.backend)?;
            let cols = out_node.shape.cols();
            let mut out = take_or_clone(m, cfg.in_place)?;
            let t0 = Instant::now();
            eval::with_scratch(|scratch| {
                fx.run_range(0, &mut out[row * cols..(row + 1) * cols], scratch)
            });
            stats.bytes += 16.0 * cols as f64;
            let rec = cfg.record.then(|| StepRecord {
                kind: step.kind(),
                elems: cols,
                flops: vtree.flops_per_elem() * cols as f64,
                bytes: 16.0 * cols as f64,
                chunk_secs: vec![t0.elapsed().as_secs_f64()],
                parallelizable: false,
            });
            (out, rec)
        }
        Step::SetElem { m, i, j, s, .. } => {
            let cols = out_node.shape.cols();
            let mut out = take_or_clone(m, cfg.in_place)?;
            let sval = s
                .data()
                .ok_or_else(|| {
                    crate::Error::Invalid("malformed plan: set_elem scalar not materialised".into())
                })?
                .as_f64()[0];
            out[i * cols + j] = sval;
            let rec = cfg.record.then(|| StepRecord {
                kind: step.kind(),
                elems: 1,
                flops: 0.0,
                bytes: 16.0,
                chunk_secs: vec![1e-8],
                parallelizable: false,
            });
            (out, rec)
        }
        Step::Gather { src, idx, .. } => {
            let s = src
                .data()
                .ok_or_else(|| {
                    crate::Error::Invalid("malformed plan: gather src not materialised".into())
                })?
                .as_f64()
                .clone();
            let ix = idx
                .data()
                .ok_or_else(|| {
                    crate::Error::Invalid("malformed plan: gather idx not materialised".into())
                })?
                .as_i64()
                .clone();
            // Validate indices up front: an out-of-range gather must be
            // a clean error, not a panic inside a shared pool worker.
            if ix.len() < out_len {
                return Err(crate::Error::Invalid(
                    "gather: index container shorter than output".into(),
                ));
            }
            if let Some(bad) = ix[..out_len].iter().find(|&&v| v < 0 || v as usize >= s.len()) {
                return Err(crate::Error::Invalid(format!(
                    "gather index {bad} out of range (source length {})",
                    s.len()
                )));
            }
            let mut out = vec![0.0f64; out_len];
            let chunks = make_chunks(out_len, cfg, workers);
            let t0 = Instant::now();
            let optr = OutPtr(out.as_mut_ptr());
            let body = |c: &Chunk| {
                let o = unsafe { optr.slice(c.start, c.len) };
                for (k, ov) in o.iter_mut().enumerate() {
                    *ov = s[ix[c.start + k] as usize];
                }
            };
            let times = run_chunked(&chunks, cfg, pool, &body);
            let _ = t0;
            stats.bytes += 24.0 * out_len as f64;
            let rec = cfg.record.then(|| StepRecord {
                kind: step.kind(),
                elems: out_len,
                flops: 0.0,
                bytes: 24.0 * out_len as f64,
                chunk_secs: times,
                parallelizable: chunks.len() > 1,
            });
            (out, rec)
        }
        Step::Scatter { src, idx, .. } => {
            let s = src
                .data()
                .ok_or_else(|| {
                    crate::Error::Invalid("malformed plan: scatter src not materialised".into())
                })?
                .as_f64()
                .clone();
            let ix = idx
                .data()
                .ok_or_else(|| {
                    crate::Error::Invalid("malformed plan: scatter idx not materialised".into())
                })?
                .as_i64()
                .clone();
            if ix.len() != s.len() {
                return Err(crate::Error::Invalid(
                    "scatter: index container length does not match source".into(),
                ));
            }
            if let Some(bad) = ix.iter().find(|&&v| v < 0 || v as usize >= out_len) {
                return Err(crate::Error::Invalid(format!(
                    "scatter index {bad} out of range (output length {out_len})"
                )));
            }
            // Writes may collide (duplicate indices: last wins), so the
            // scatter stays serial — it is a materialising permutation,
            // not a hot loop.
            let t0 = Instant::now();
            let mut out = vec![0.0f64; out_len];
            for (k, &i) in ix.iter().enumerate() {
                out[i as usize] = s[k];
            }
            stats.bytes += 24.0 * s.len() as f64 + 8.0 * out_len as f64;
            let rec = cfg.record.then(|| StepRecord {
                kind: step.kind(),
                elems: out_len,
                flops: 0.0,
                bytes: 24.0 * s.len() as f64 + 8.0 * out_len as f64,
                chunk_secs: vec![t0.elapsed().as_secs_f64()],
                parallelizable: false,
            });
            (out, rec)
        }
        Step::Map { out } => {
            let op = out.op.borrow();
            let mf = match &*op {
                Op::Map(f) => f,
                _ => {
                    return Err(crate::Error::Invalid(
                        "malformed plan: Map step on non-map node".into(),
                    ))
                }
            };
            // Resolve captures in order, split by dtype.
            let mut f64s: Vec<Arc<Vec<f64>>> = Vec::new();
            let mut i64s: Vec<Arc<Vec<i64>>> = Vec::new();
            for c in &mf.captures {
                match c.data().ok_or_else(|| {
                    crate::Error::Invalid(
                        "malformed plan: map capture not materialised".into(),
                    )
                })? {
                    Data::F64(v) => f64s.push(v),
                    Data::I64(v) => i64s.push(v),
                }
            }
            let f = mf.f.clone();
            let fpe = mf.flops_per_elem;
            let bpe = mf.bytes_per_elem;
            drop(op);
            let mut outv = vec![0.0f64; out_len];
            // map grain: elemental calls are much heavier than stream ops
            let map_cfg = EngineCfg {
                tuning: tuning::Tuning { grain: (cfg.tuning.grain / 16).max(64), ..cfg.tuning },
                ..*cfg
            };
            let chunks = make_chunks(out_len, &map_cfg, workers);
            let optr = OutPtr(outv.as_mut_ptr());
            let f64refs: Vec<&[f64]> = f64s.iter().map(|a| a.as_slice()).collect();
            let i64refs: Vec<&[i64]> = i64s.iter().map(|a| a.as_slice()).collect();
            let body = |c: &Chunk| {
                let o = unsafe { optr.slice(c.start, c.len) };
                let args = MapArgs { f64s: f64refs.clone(), i64s: i64refs.clone() };
                for (k, ov) in o.iter_mut().enumerate() {
                    *ov = f(&args, c.start + k);
                }
            };
            let times = run_chunked(&chunks, cfg, pool, &body);
            stats.flops += fpe * out_len as f64;
            stats.bytes += bpe * out_len as f64;
            let rec = cfg.record.then(|| StepRecord {
                kind: step.kind(),
                elems: out_len,
                flops: fpe * out_len as f64,
                bytes: bpe * out_len as f64,
                chunk_secs: times,
                parallelizable: chunks.len() > 1,
            });
            (outv, rec)
        }
    };

    out_node.materialize(Data::F64(Arc::new(result)));
    if let Some(r) = record {
        stats.records.push(r);
    }
    Ok(())
}

/// Validate a CSR row-pointer array before handing it to the segmented
/// executor: a malformed `segp` must be a clean [`crate::Error::Invalid`]
/// (a pool worker survives), never an out-of-bounds panic. Shared with
/// the serving replay path.
pub(crate) fn validate_segp(segp: &[i64], rows: usize, nnz: usize) -> crate::Result<()> {
    if segp.len() != rows + 1 {
        return Err(crate::Error::Invalid(format!(
            "segmented reduce: row-pointer length {} != rows+1 ({})",
            segp.len(),
            rows + 1
        )));
    }
    let mut prev = 0i64;
    for &v in segp {
        if v < prev {
            return Err(crate::Error::Invalid(
                "segmented reduce: row pointers not monotone non-negative".into(),
            ));
        }
        prev = v;
    }
    if prev as usize > nnz {
        return Err(crate::Error::Invalid(format!(
            "segmented reduce: row pointers end at {prev}, beyond the {nnz}-element operand"
        )));
    }
    Ok(())
}

fn make_row_chunks(total: usize, grain: usize, cfg: &EngineCfg, workers: usize) -> Vec<Chunk> {
    let sub = EngineCfg { tuning: tuning::Tuning { grain, ..cfg.tuning }, ..*cfg };
    make_chunks(total, &sub, workers)
}

/// Run chunks serially or on the pool, optionally timing each chunk.
/// Returns per-chunk seconds when recording.
fn run_chunked(
    chunks: &[Chunk],
    cfg: &EngineCfg,
    pool: Option<&SharedPool>,
    body: &(dyn Fn(&Chunk) + Sync),
) -> Vec<f64> {
    let use_pool = matches!(cfg.mode, Mode::Parallel) && chunks.len() > 1 && pool.is_some();
    if cfg.record {
        let slots: Vec<AtomicU64> = (0..chunks.len()).map(|_| AtomicU64::new(0)).collect();
        let timed = |i: usize| {
            let t0 = Instant::now();
            body(&chunks[i]);
            slots[i].store(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        };
        if use_pool {
            pool.unwrap().run_chunks(chunks.len(), &timed);
        } else {
            for i in 0..chunks.len() {
                timed(i);
            }
        }
        slots.iter().map(|s| s.load(Ordering::Relaxed) as f64 * 1e-9).collect()
    } else {
        if use_pool {
            pool.unwrap().run_chunks(chunks.len(), &|i| body(&chunks[i]));
        } else {
            for c in chunks {
                body(c);
            }
        }
        vec![]
    }
}

fn run_elementwise(
    fx: &Tape,
    out: &mut [f64],
    chunks: &[Chunk],
    cfg: &EngineCfg,
    pool: Option<&SharedPool>,
) -> Option<Vec<f64>> {
    let optr = OutPtr(out.as_mut_ptr());
    let body = |c: &Chunk| {
        let o = unsafe { optr.slice(c.start, c.len) };
        eval::with_scratch(|scratch| fx.run_range(c.start, o, scratch));
    };
    let times = run_chunked(chunks, cfg, pool, &body);
    cfg.record.then_some(times)
}

fn run_reduce_rows(
    fx: &Tape,
    red: RedOp,
    out: &mut [f64],
    cols: usize,
    chunks: &[Chunk],
    cfg: &EngineCfg,
    pool: Option<&SharedPool>,
) -> Option<Vec<f64>> {
    let optr = OutPtr(out.as_mut_ptr());
    let bk = fx.backend();
    let body = |c: &Chunk| {
        let o = unsafe { optr.slice(c.start, c.len) };
        eval::with_scratch(|scratch| {
            let mut buf = scratch.take();
            for (k, ov) in o.iter_mut().enumerate() {
                let r = c.start + k;
                // Per-register tree-combine: the tape fills a register
                // block, the reduction folds it — no tree re-walk per
                // row block.
                let mut acc = red.identity();
                let mut off = 0;
                while off < cols {
                    let len = BLOCK.min(cols - off);
                    fx.run_range(r * cols + off, &mut buf[..len], scratch);
                    acc = red.fold(acc, bk.fold_slice(red, &buf[..len]));
                    off += len;
                }
                *ov = acc;
            }
            scratch.put(buf);
        });
    };
    let times = run_chunked(chunks, cfg, pool, &body);
    cfg.record.then_some(times)
}

fn run_reduce_cols(
    fx: &Tape,
    red: RedOp,
    out: &mut [f64],
    rows: usize,
    cols: usize,
    chunks: &[Chunk],
    cfg: &EngineCfg,
    pool: Option<&SharedPool>,
) -> Option<Vec<f64>> {
    let optr = OutPtr(out.as_mut_ptr());
    let body = |c: &Chunk| {
        // Columns [c.start, c.start+c.len): stream rows, fold element-wise.
        let o = unsafe { optr.slice(c.start, c.len) };
        eval::with_scratch(|scratch| {
            let mut buf = scratch.take();
            for r in 0..rows {
                let mut off = 0;
                while off < c.len {
                    let len = BLOCK.min(c.len - off);
                    fx.run_range(r * cols + c.start + off, &mut buf[..len], scratch);
                    for k in 0..len {
                        o[off + k] = red.fold(o[off + k], buf[k]);
                    }
                    off += len;
                }
            }
            scratch.put(buf);
        });
    };
    let times = run_chunked(chunks, cfg, pool, &body);
    cfg.record.then_some(times)
}

fn run_reduce_all(
    fx: &Tape,
    red: RedOp,
    len: usize,
    chunks: &[Chunk],
    cfg: &EngineCfg,
    pool: Option<&SharedPool>,
) -> (f64, Option<Vec<f64>>) {
    if chunks.is_empty() {
        return (red.identity(), cfg.record.then_some(vec![]));
    }
    let partials: Vec<AtomicU64> =
        (0..chunks.len()).map(|_| AtomicU64::new(red.identity().to_bits())).collect();
    let bk = fx.backend();
    let body = |c: &Chunk| {
        let idx = chunks.iter().position(|x| x.start == c.start).unwrap();
        eval::with_scratch(|scratch| {
            let mut buf = scratch.take();
            let mut acc = red.identity();
            let mut off = 0;
            while off < c.len {
                let l = BLOCK.min(c.len - off);
                fx.run_range(c.start + off, &mut buf[..l], scratch);
                acc = red.fold(acc, bk.fold_slice(red, &buf[..l]));
                off += l;
            }
            partials[idx].store(acc.to_bits(), Ordering::Relaxed);
            scratch.put(buf);
        });
    };
    let times = run_chunked(chunks, cfg, pool, &body);
    let mut acc = red.identity();
    for p in &partials {
        acc = red.fold(acc, f64::from_bits(p.load(Ordering::Relaxed)));
    }
    let _ = len;
    (acc, cfg.record.then_some(times))
}
