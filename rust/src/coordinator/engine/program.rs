//! The program executor: whole-kernel replay of a captured
//! [`Program`]'s loop nest.
//!
//! The capture half lives in [`crate::coordinator::program`]: the
//! builder records statements, the buffer planner fixes every value to
//! an arena slot, and each statement's expression is compiled **once**
//! into a [`TapeProgram`]. This module owns the replay half:
//!
//!  * [`Program::invoke_into`] walks the structured step tree — `_for`
//!    nodes replay their bodies `trip` times — executing each step's
//!    pre-compiled tape against per-invocation slot buffers.
//!  * All mutable state (slot buffers, scalar registers, front/back
//!    flip bits, raw leaf-binding scratch) lives in a `ProgState`
//!    recycled through a per-program stash, exactly like the serving
//!    layer's replay arenas: a steady-state invocation performs **zero
//!    heap allocations** (`rust/tests/serve_alloc.rs`).
//!  * [`Program::invoke_pooled`] fans each element-wise step's
//!    capture-time chunk table and the spmv's row range out over a
//!    [`SharedPool`] — chunks write disjoint ranges, so pooled replay
//!    is bit-identical to serial replay. Reductions stay serial to
//!    preserve the host BLAS association (bit-identity with the eager
//!    drivers matters more than parallel dots).
//!
//! Double-buffered carried vectors resolve their front/back slot at
//! replay time through the state's flip bits (reset per invocation), so
//! one compiled step stream serves every iteration parity.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::profile::{self, OpClass};

use super::backend::spmv_row_serial;
use super::eval::{with_scratch, ILeafBind, LeafBind, TapeProgram};
use super::pool::SharedPool;
use crate::coordinator::node::Data;
use crate::coordinator::ops::BinOp;
use crate::kernels::blas1;
use crate::{Error, Result};

/// Element-wise steps larger than this are split into chunks at capture
/// so pooled replay has work to distribute.
const EMIT_GRAIN: usize = 8192;

/// Where a compiled step's tape leaf reads from at replay time.
#[derive(Debug, Clone, Copy)]
pub(crate) enum PBind {
    /// Invocation parameter (raw binding filled at invoke entry).
    Param(usize),
    /// Fixed arena slot (temporaries, unpaired carried vectors).
    Slot(usize),
    /// Front buffer of a double-buffered pair (resolved per replay).
    Front(usize),
    /// Baked capture-time constant.
    Baked(usize),
    /// The scalar register file (splat reads index it).
    Sregs,
}

/// A compiled step's write target.
#[derive(Debug, Clone, Copy)]
pub(crate) enum PDst {
    Slot(usize),
    /// Front buffer of a pair (plain overwrite of a paired vector).
    Front(usize),
    /// Back buffer of a pair (staged region writes before a flip).
    Back(usize),
}

/// A fused element-wise write of a compiled tape into a slot region.
#[derive(Debug)]
pub(crate) struct EmitStep {
    dst: PDst,
    off: usize,
    len: usize,
    prog: TapeProgram,
    binds: Vec<PBind>,
    /// Baked i64 table indices for the tape's gather loaders.
    ibinds: Vec<usize>,
    /// Region-relative chunk table for pooled replay.
    chunks: Vec<(usize, usize)>,
}

impl EmitStep {
    pub(crate) fn new(
        dst: PDst,
        off: usize,
        len: usize,
        prog: TapeProgram,
        binds: Vec<PBind>,
        ibinds: Vec<usize>,
    ) -> EmitStep {
        let mut chunks = Vec::new();
        let mut s = 0;
        while s < len {
            let l = EMIT_GRAIN.min(len - s);
            chunks.push((s, l));
            s += l;
        }
        EmitStep { dst, off, len, prog, binds, ibinds, chunks }
    }
}

/// One compiled program step.
#[derive(Debug)]
pub(crate) enum CStep {
    Emit(EmitStep),
    /// Flip a double-buffered pair (O(1) — the `cat` replacement).
    Flip { pair: usize },
    /// CSR spmv replicating [`crate::sparse::Csr::spmv`] bit-for-bit.
    Spmv { dst: PDst, vals: usize, indx: usize, rowp: usize, x: PBind, rows: usize },
    /// Dot product via [`crate::kernels::blas1::dot`] (host-CG
    /// association).
    Dot { dst: usize, a: PBind, b: PBind },
    SBin { op: BinOp, dst: usize, a: Sreg, b: Sreg },
    SSet { dst: usize, src: usize },
}

pub(crate) type Sreg = usize;

/// Structured step tree: the compiled `_for` loop IR.
#[derive(Debug)]
pub(crate) enum CNode {
    Step(usize),
    /// `uniform` loops replay `bodies[0]` `trip` times; staged loops
    /// hold one body per iteration (`bodies.len() == trip`).
    For { trip: usize, uniform: bool, bodies: Vec<Vec<CNode>> },
}

/// Per-invocation mutable state, recycled through the program's stash.
#[derive(Default)]
struct ProgState {
    slots: Vec<Vec<f64>>,
    sregs: Vec<f64>,
    flips: Vec<bool>,
    parambuf: Vec<LeafBind>,
    leafbuf: Vec<LeafBind>,
    ileafbuf: Vec<ILeafBind>,
}

// SAFETY: the raw bindings in `parambuf`/`leafbuf`/`ileafbuf` are only
// dereferenced inside the invocation that wrote them and are cleared
// before the state returns to the stash; nothing dangling crosses
// threads.
unsafe impl Send for ProgState {}

impl ProgState {
    fn prepare(&mut self, prog: &Program) {
        if self.slots.len() != prog.slot_lens.len() {
            self.slots.resize_with(prog.slot_lens.len(), Vec::new);
        }
        for (s, &l) in self.slots.iter_mut().zip(&prog.slot_lens) {
            if s.len() != l {
                s.resize(l, 0.0);
            }
        }
        if self.sregs.len() != prog.n_sregs {
            self.sregs.resize(prog.n_sregs, 0.0);
        }
        self.flips.clear();
        self.flips.resize(prog.pairs.len(), false);
    }
}

/// Replay counters of one captured program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProgStats {
    /// Total invocations (whole-kernel replays).
    pub replays: u64,
    /// States ever created; plateaus at the peak number of concurrent
    /// invocations, so `replays >> states_created` in steady state.
    pub states_created: u64,
}

/// A captured, compiled, replay-many program: the `arbb::call()`
/// artifact. Fully owned and `Send + Sync` — any number of threads can
/// invoke the same program concurrently, each replay drawing its state
/// from the recycled stash.
///
/// Build one with [`crate::coordinator::program::ProgramBuilder`].
pub struct Program {
    param_lens: Vec<usize>,
    baked_f: Vec<Arc<Vec<f64>>>,
    baked_i: Vec<Arc<Vec<i64>>>,
    steps: Vec<CStep>,
    structure: Vec<CNode>,
    slot_lens: Vec<usize>,
    pairs: Vec<(usize, usize)>,
    n_sregs: usize,
    outputs: Vec<PBind>,
    out_len: usize,
    states: Mutex<Vec<ProgState>>,
    replays: AtomicU64,
    states_created: AtomicU64,
}

#[allow(dead_code)]
fn _assert_send_sync() {
    fn ok<T: Send + Sync>() {}
    ok::<Program>();
}

impl Program {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        param_lens: Vec<usize>,
        baked_f: Vec<Arc<Vec<f64>>>,
        baked_i: Vec<Arc<Vec<i64>>>,
        steps: Vec<CStep>,
        structure: Vec<CNode>,
        slot_lens: Vec<usize>,
        pairs: Vec<(usize, usize)>,
        n_sregs: usize,
        outputs: Vec<PBind>,
        out_len: usize,
    ) -> Program {
        Program {
            param_lens,
            baked_f,
            baked_i,
            steps,
            structure,
            slot_lens,
            pairs,
            n_sregs,
            outputs,
            out_len,
            states: Mutex::new(Vec::new()),
            replays: AtomicU64::new(0),
            states_created: AtomicU64::new(0),
        }
    }

    pub fn n_params(&self) -> usize {
        self.param_lens.len()
    }

    /// Declared length of parameter `i`.
    pub fn param_len(&self, i: usize) -> usize {
        self.param_lens[i]
    }

    /// Total invocation output length (outputs concatenated).
    pub fn out_len(&self) -> usize {
        self.out_len
    }

    /// Compiled steps (statements; loop bodies count once).
    pub fn n_steps(&self) -> usize {
        self.steps.len()
    }

    /// Arena slots the buffer plan assigned.
    pub fn n_slots(&self) -> usize {
        self.slot_lens.len()
    }

    /// Double-buffered front/back pairs.
    pub fn n_pairs(&self) -> usize {
        self.pairs.len()
    }

    /// Total f64 elements of arena storage per invocation state.
    pub fn slot_elems(&self) -> usize {
        self.slot_lens.iter().sum()
    }

    /// Trip counts of the program's `_for` nodes, in capture order.
    pub fn loop_trips(&self) -> Vec<usize> {
        fn collect(nodes: &[CNode], out: &mut Vec<usize>) {
            for n in nodes {
                if let CNode::For { trip, bodies, .. } = n {
                    out.push(*trip);
                    for b in bodies {
                        collect(b, out);
                    }
                }
            }
        }
        let mut out = Vec::new();
        collect(&self.structure, &mut out);
        out
    }

    pub fn stats(&self) -> ProgStats {
        ProgStats {
            replays: self.replays.load(Ordering::Relaxed),
            states_created: self.states_created.load(Ordering::Relaxed),
        }
    }

    /// Invoke against slice arguments, returning a fresh output vector.
    pub fn invoke(&self, args: &[&[f64]]) -> Result<Vec<f64>> {
        let mut out = Vec::new();
        self.invoke_into(args, &mut out)?;
        Ok(out)
    }

    /// Invoke against slice arguments, writing the concatenated outputs
    /// into `out` (cleared; capacity reused — steady state allocates
    /// nothing).
    pub fn invoke_into(&self, args: &[&[f64]], out: &mut Vec<f64>) -> Result<()> {
        let mut st = self.take_state(args.len())?;
        for (i, a) in args.iter().enumerate() {
            if a.len() != self.param_lens[i] {
                self.put_state(st);
                return Err(invalid_arg(i, self.param_lens[i], a.len()));
            }
            st.parambuf.push((a.as_ptr(), a.len()));
        }
        let r = self.run(&mut st, None, out);
        self.put_state(st);
        r
    }

    /// Invoke against request [`Data`] buffers (the serving path; f64
    /// parameters only).
    pub fn invoke_data(&self, args: &[Data], out: &mut Vec<f64>) -> Result<()> {
        let mut st = self.take_state(args.len())?;
        for (i, a) in args.iter().enumerate() {
            let v = match a {
                Data::F64(v) => v,
                Data::I64(_) => {
                    self.put_state(st);
                    return Err(Error::Invalid(format!(
                        "program argument {i}: i64 parameters are not supported \
                         (bake index tables at capture)"
                    )));
                }
            };
            if v.len() != self.param_lens[i] {
                self.put_state(st);
                return Err(invalid_arg(i, self.param_lens[i], v.len()));
            }
            st.parambuf.push((v.as_ptr(), v.len()));
        }
        let r = self.run(&mut st, None, out);
        self.put_state(st);
        r
    }

    /// Invoke with element-wise steps and the spmv row sweep fanned out
    /// over `pool` (bit-identical to serial replay — chunks write
    /// disjoint ranges and reductions stay serial).
    pub fn invoke_pooled(
        &self,
        args: &[&[f64]],
        out: &mut Vec<f64>,
        pool: &SharedPool,
    ) -> Result<()> {
        let mut st = self.take_state(args.len())?;
        for (i, a) in args.iter().enumerate() {
            if a.len() != self.param_lens[i] {
                self.put_state(st);
                return Err(invalid_arg(i, self.param_lens[i], a.len()));
            }
            st.parambuf.push((a.as_ptr(), a.len()));
        }
        let r = self.run(&mut st, Some(pool), out);
        self.put_state(st);
        r
    }

    // -- replay internals ---------------------------------------------

    fn take_state(&self, n_args: usize) -> Result<ProgState> {
        if n_args != self.param_lens.len() {
            return Err(Error::Invalid(format!(
                "program expects {} arguments, got {n_args}",
                self.param_lens.len()
            )));
        }
        let st = match self.states.lock().unwrap().pop() {
            Some(s) => s,
            None => {
                self.states_created.fetch_add(1, Ordering::Relaxed);
                ProgState::default()
            }
        };
        Ok(st)
    }

    fn put_state(&self, mut st: ProgState) {
        st.parambuf.clear();
        st.leafbuf.clear();
        st.ileafbuf.clear();
        self.states.lock().unwrap().push(st);
    }

    fn run(&self, st: &mut ProgState, pool: Option<&SharedPool>, out: &mut Vec<f64>) -> Result<()> {
        self.replays.fetch_add(1, Ordering::Relaxed);
        st.prepare(self);
        self.exec_nodes(&self.structure, st, pool)?;
        out.clear();
        for o in &self.outputs {
            // SAFETY: parameter bindings point into the caller's argument
            // slices, alive for this call.
            let s = unsafe {
                rd_slice(o, &st.parambuf, &st.slots, &self.baked_f, &self.pairs, &st.flips)?
            };
            out.extend_from_slice(s);
        }
        Ok(())
    }

    fn exec_nodes(
        &self,
        nodes: &[CNode],
        st: &mut ProgState,
        pool: Option<&SharedPool>,
    ) -> Result<()> {
        for n in nodes {
            match n {
                CNode::Step(i) => self.exec_step(&self.steps[*i], st, pool)?,
                CNode::For { trip, uniform, bodies } => {
                    if *uniform {
                        for _ in 0..*trip {
                            self.exec_nodes(&bodies[0], st, pool)?;
                        }
                    } else {
                        for b in bodies {
                            self.exec_nodes(b, st, pool)?;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    fn exec_step(
        &self,
        step: &CStep,
        st: &mut ProgState,
        pool: Option<&SharedPool>,
    ) -> Result<()> {
        let ProgState { slots, sregs, flips, parambuf, leafbuf, ileafbuf } = st;
        match step {
            CStep::Emit(e) => {
                let di = dst_slot(&self.pairs, flips, e.dst);
                let mut ob = std::mem::take(&mut slots[di]);
                leafbuf.clear();
                for b in &e.binds {
                    let (p, l): (*const f64, usize) = match b {
                        PBind::Param(i) => parambuf[*i],
                        PBind::Slot(s) => {
                            debug_assert_ne!(*s, di, "bind aliases the output slot");
                            (slots[*s].as_ptr(), slots[*s].len())
                        }
                        PBind::Front(p) => {
                            let s = front_of(&self.pairs, flips, *p);
                            debug_assert_ne!(s, di, "front bind aliases the output slot");
                            (slots[s].as_ptr(), slots[s].len())
                        }
                        PBind::Baked(i) => {
                            (self.baked_f[*i].as_ptr(), self.baked_f[*i].len())
                        }
                        PBind::Sregs => (sregs.as_ptr(), sregs.len()),
                    };
                    leafbuf.push((p, l));
                }
                ileafbuf.clear();
                for &i in &e.ibinds {
                    ileafbuf.push((self.baked_i[i].as_ptr(), self.baked_i[i].len()));
                }
                let out = &mut ob[e.off..e.off + e.len];
                match pool {
                    Some(p) if e.chunks.len() > 1 => {
                        let share = PooledEmit {
                            prog: &e.prog,
                            leaf: leafbuf.as_ptr(),
                            n_leaf: leafbuf.len(),
                            ileaf: ileafbuf.as_ptr(),
                            n_ileaf: ileafbuf.len(),
                            out: out.as_mut_ptr(),
                        };
                        p.run_chunks(e.chunks.len(), &|ci| {
                            let (c0, cl) = e.chunks[ci];
                            // SAFETY: chunks cover disjoint output
                            // ranges; bindings outlive the barrier.
                            unsafe { share.run(c0, cl) };
                        });
                    }
                    _ => {
                        // SAFETY: the bindings point into parameters,
                        // other slots, baked buffers and the scalar
                        // registers — all alive across the call and
                        // disjoint from the taken output slot (Acc
                        // reads register 0, which *is* the output).
                        // The TLS scratch is taken per step, never held
                        // across the walk — pooled steps re-enter it on
                        // the participating calling thread.
                        with_scratch(|scratch| unsafe {
                            e.prog.run_range_raw(leafbuf, ileafbuf, 0, out, scratch)
                        });
                    }
                }
                slots[di] = ob;
            }
            CStep::Flip { pair } => flips[*pair] = !flips[*pair],
            CStep::Spmv { dst, vals, indx, rowp, x, rows } => {
                let di = dst_slot(&self.pairs, flips, *dst);
                let mut ob = std::mem::take(&mut slots[di]);
                {
                    // SAFETY: parameter bindings are alive for this call.
                    let xs = unsafe {
                        rd_slice(x, parambuf, slots, &self.baked_f, &self.pairs, flips)?
                    };
                    let vals = &self.baked_f[*vals];
                    let indx = &self.baked_i[*indx];
                    let rowp = &self.baked_i[*rowp];
                    let body = |r0: usize, o: &mut [f64]| {
                        for (j, ov) in o.iter_mut().enumerate() {
                            let r = r0 + j;
                            *ov = spmv_row_serial(
                                vals,
                                indx,
                                xs,
                                rowp[r] as usize,
                                rowp[r + 1] as usize,
                            );
                        }
                    };
                    let t0 = profile::enabled().then(Instant::now);
                    match pool {
                        Some(p) if *rows >= 2048 => {
                            let nchunks = (*rows / 512).clamp(1, 64);
                            let per = (*rows + nchunks - 1) / nchunks;
                            let share = PooledRows { out: ob.as_mut_ptr(), rows: *rows, per };
                            let f = &body;
                            p.run_chunks(nchunks, &|ci| {
                                let r0 = ci * share.per;
                                let r1 = (r0 + share.per).min(share.rows);
                                if r0 < r1 {
                                    // SAFETY: disjoint row ranges.
                                    let o = unsafe {
                                        std::slice::from_raw_parts_mut(
                                            share.out.add(r0),
                                            r1 - r0,
                                        )
                                    };
                                    f(r0, o);
                                }
                            });
                        }
                        _ => body(0, &mut ob[..*rows]),
                    }
                    if let Some(t0) = t0 {
                        let nnz = rowp[*rows].saturating_sub(rowp[0]).max(0) as u64;
                        profile::record_sample(
                            OpClass::SpmvSerial,
                            nnz,
                            t0.elapsed().as_nanos() as u64,
                        );
                    }
                }
                slots[di] = ob;
            }
            CStep::Dot { dst, a, b } => {
                let t0 = profile::enabled().then(Instant::now);
                // SAFETY: as above; dot operands are never the scalar
                // register file, so writing `sregs` below cannot alias.
                let (v, n) = unsafe {
                    let av = rd_slice(a, parambuf, slots, &self.baked_f, &self.pairs, flips)?;
                    let bv = rd_slice(b, parambuf, slots, &self.baked_f, &self.pairs, flips)?;
                    (blas1::dot(av, bv), av.len())
                };
                if let Some(t0) = t0 {
                    profile::record_sample(OpClass::Dot, n as u64, t0.elapsed().as_nanos() as u64);
                }
                sregs[*dst] = v;
            }
            CStep::SBin { op, dst, a, b } => {
                sregs[*dst] = sbin_apply(*op, sregs[*a], sregs[*b]);
            }
            CStep::SSet { dst, src } => sregs[*dst] = sregs[*src],
        }
        Ok(())
    }
}

/// Pooled element-wise chunk sharing (raw pointers behind a Sync
/// wrapper; the pool barrier bounds every dereference).
struct PooledEmit<'a> {
    prog: &'a TapeProgram,
    leaf: *const LeafBind,
    n_leaf: usize,
    ileaf: *const ILeafBind,
    n_ileaf: usize,
    out: *mut f64,
}

// SAFETY: chunk bodies write disjoint output ranges and read the shared
// immutable bindings; `run_chunks` blocks until every chunk completes.
unsafe impl Sync for PooledEmit<'_> {}

impl PooledEmit<'_> {
    /// # Safety
    /// Caller guarantees `(c0, cl)` ranges are disjoint across
    /// concurrent calls and in range.
    unsafe fn run(&self, c0: usize, cl: usize) {
        let leaves = std::slice::from_raw_parts(self.leaf, self.n_leaf);
        let ileaves = std::slice::from_raw_parts(self.ileaf, self.n_ileaf);
        let o = std::slice::from_raw_parts_mut(self.out.add(c0), cl);
        with_scratch(|s| self.prog.run_range_raw(leaves, ileaves, c0, o, s));
    }
}

struct PooledRows {
    out: *mut f64,
    rows: usize,
    per: usize,
}

// SAFETY: as `PooledEmit` — disjoint row ranges under a pool barrier.
unsafe impl Sync for PooledRows {}

fn dst_slot(pairs: &[(usize, usize)], flips: &[bool], dst: PDst) -> usize {
    match dst {
        PDst::Slot(s) => s,
        PDst::Front(p) => front_of(pairs, flips, p),
        PDst::Back(p) => back_of(pairs, flips, p),
    }
}

fn front_of(pairs: &[(usize, usize)], flips: &[bool], p: usize) -> usize {
    if flips[p] {
        pairs[p].1
    } else {
        pairs[p].0
    }
}

fn back_of(pairs: &[(usize, usize)], flips: &[bool], p: usize) -> usize {
    if flips[p] {
        pairs[p].0
    } else {
        pairs[p].1
    }
}

/// Resolve a read binding to its slice for this replay.
///
/// # Safety
/// `Param` bindings must point into argument slices alive for the
/// caller's borrow of the returned slice.
unsafe fn rd_slice<'a>(
    bind: &PBind,
    parambuf: &[LeafBind],
    slots: &'a [Vec<f64>],
    baked_f: &'a [Arc<Vec<f64>>],
    pairs: &[(usize, usize)],
    flips: &[bool],
) -> Result<&'a [f64]> {
    Ok(match bind {
        PBind::Param(i) => {
            let (p, l) = parambuf[*i];
            std::slice::from_raw_parts(p, l)
        }
        PBind::Slot(s) => &slots[*s],
        PBind::Front(p) => &slots[front_of(pairs, flips, *p)],
        PBind::Baked(i) => baked_f[*i].as_slice(),
        PBind::Sregs => {
            return Err(Error::Invalid(
                "program: scalar register file is not vector-readable".into(),
            ))
        }
    })
}

fn sbin_apply(op: BinOp, a: f64, b: f64) -> f64 {
    match op {
        BinOp::Add => a + b,
        BinOp::Sub => a - b,
        BinOp::Mul => a * b,
        BinOp::Div => a / b,
        BinOp::Min => a.min(b),
        BinOp::Max => a.max(b),
    }
}

fn invalid_arg(i: usize, want: usize, got: usize) -> Error {
    Error::Invalid(format!("program argument {i}: expected length {want}, got {got}"))
}
