//! Consolidated tuning constants for every lowering the engine and the
//! serving path choose between.
//!
//! Before this module the knobs were scattered: the tape block size
//! lived in `eval.rs`, dgemm panel heights in `kernels/dgemm.rs`, the
//! chunk fan-out in `Context::try_force`, the serve batch ceiling in
//! `ServeConfig`, and the segmented-spmv path choice was implicit in
//! whether a caller passed `runs_hint`. The plan explorer
//! ([`crate::coordinator::passes::explore`]) varies these parameters to
//! enumerate candidate lowerings, so they live in one [`Tuning`] struct
//! threaded through [`super::EngineCfg`]; the defaults reproduce the
//! pre-explorer hard-coded behaviour bit for bit.

use crate::{Error, Result};

/// Tape evaluation block length (elements per register lane).
///
/// A compile-time constant — the tape register file is laid out as
/// `n_scratch × BLOCK` lanes — so it is not runtime-explorable; it
/// lives here so every sizing constant has one home. 2048 elements =
/// 16 KiB per lane: half of a typical 32 KiB L1D, leaving room for two
/// streaming operands.
pub const BLOCK: usize = 2048;

/// Which segmented-reduction path [`super::eval::SegTape`] dispatches.
///
/// All three paths are bit-identical by contract (they share the
/// `RedOp::fold_segment_chunk` association), so forcing one is always
/// safe — only the per-element cost changes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SegPath {
    /// Capture-time heuristic: fused superinstruction when the spmv
    /// pattern matches, contiguity runs when the caller hints them,
    /// blocked tape otherwise (the pre-explorer behaviour).
    #[default]
    Auto,
    /// Force the general blocked tape-fill path.
    Blocked,
    /// Force the fused `GatherMulSegSum` superinstruction (falls back
    /// to blocked when the pattern did not match).
    Fused,
    /// Force contiguity-run detection even without a caller hint
    /// (falls back to fused/blocked when impossible).
    Runs,
}

impl SegPath {
    pub fn as_str(&self) -> &'static str {
        match self {
            SegPath::Auto => "auto",
            SegPath::Blocked => "blocked",
            SegPath::Fused => "fused",
            SegPath::Runs => "runs",
        }
    }

    pub fn parse(s: &str) -> Result<SegPath> {
        match s {
            "auto" => Ok(SegPath::Auto),
            "blocked" => Ok(SegPath::Blocked),
            "fused" => Ok(SegPath::Fused),
            "runs" => Ok(SegPath::Runs),
            other => Err(Error::Invalid(format!("unknown seg path {other:?}"))),
        }
    }
}

/// Every runtime-tunable lowering parameter, in one place.
///
/// `Default` reproduces the historical hard-coded values exactly; the
/// explorer produces non-default instances per (kernel, shape,
/// backend) and the plan store persists them as `k=v` lists
/// ([`Tuning::to_kv`] / [`Tuning::from_kv`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Tuning {
    /// Minimum elements per pool chunk (was `Options::grain`'s
    /// hard-coded default).
    pub grain: usize,
    /// Target chunks per pool worker — load-balancing slack (was
    /// hard-coded `4` in `Context::try_force`).
    pub chunks_per_worker: usize,
    /// Total elements below which a parallel-mode sweep stays serial
    /// anyway (`0` = disabled, the historical behaviour: the grain
    /// floor alone decides).
    pub pooled_cutoff: usize,
    /// Segmented-reduction path override.
    pub seg_path: SegPath,
    /// dgemm row-panel height (`MC`): rows of A packed per macro-tile.
    pub dgemm_mc: usize,
    /// dgemm depth-panel size (`KC`).
    pub dgemm_kc: usize,
    /// dgemm column-panel width (`NC`).
    pub dgemm_nc: usize,
    /// Serve batch-coalescing ceiling (was `ServeConfig::max_batch`'s
    /// hard-coded default).
    pub max_batch: usize,
    /// Serve batch-coalescing cost budget: a dispatcher stops growing a
    /// batch when the members' estimated cost exceeds the nearest
    /// deadline slack plus this many nanoseconds.
    pub coalesce_budget_ns: u64,
}

impl Default for Tuning {
    fn default() -> Self {
        Tuning {
            grain: 4096,
            chunks_per_worker: 4,
            pooled_cutoff: 0,
            seg_path: SegPath::Auto,
            dgemm_mc: 128,
            dgemm_kc: 256,
            dgemm_nc: 512,
            max_batch: 32,
            coalesce_budget_ns: 0,
        }
    }
}

impl Tuning {
    /// Serialise as a `k=v,…` list (only the fields that differ from
    /// default, so stores stay small and forward-readable).
    pub fn to_kv(&self) -> String {
        let d = Tuning::default();
        let mut parts: Vec<String> = Vec::new();
        if self.grain != d.grain {
            parts.push(format!("grain={}", self.grain));
        }
        if self.chunks_per_worker != d.chunks_per_worker {
            parts.push(format!("cpw={}", self.chunks_per_worker));
        }
        if self.pooled_cutoff != d.pooled_cutoff {
            parts.push(format!("cutoff={}", self.pooled_cutoff));
        }
        if self.seg_path != d.seg_path {
            parts.push(format!("seg={}", self.seg_path.as_str()));
        }
        if self.dgemm_mc != d.dgemm_mc {
            parts.push(format!("mc={}", self.dgemm_mc));
        }
        if self.dgemm_kc != d.dgemm_kc {
            parts.push(format!("kc={}", self.dgemm_kc));
        }
        if self.dgemm_nc != d.dgemm_nc {
            parts.push(format!("nc={}", self.dgemm_nc));
        }
        if self.max_batch != d.max_batch {
            parts.push(format!("batch={}", self.max_batch));
        }
        if self.coalesce_budget_ns != d.coalesce_budget_ns {
            parts.push(format!("coalesce={}", self.coalesce_budget_ns));
        }
        if parts.is_empty() {
            "-".to_string()
        } else {
            parts.join(",")
        }
    }

    /// Parse a `k=v,…` list produced by [`Tuning::to_kv`]; unknown keys
    /// are a hard error so a corrupted store line cannot silently load
    /// as defaults.
    pub fn from_kv(s: &str) -> Result<Tuning> {
        let mut t = Tuning::default();
        if s == "-" || s.is_empty() {
            return Ok(t);
        }
        for kv in s.split(',') {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| Error::Invalid(format!("tuning entry {kv:?} is not k=v")))?;
            let num = || -> Result<usize> {
                v.parse().map_err(|_| Error::Invalid(format!("tuning {k}={v:?}: not a number")))
            };
            match k {
                "grain" => t.grain = num()?,
                "cpw" => t.chunks_per_worker = num()?,
                "cutoff" => t.pooled_cutoff = num()?,
                "seg" => t.seg_path = SegPath::parse(v)?,
                "mc" => t.dgemm_mc = num()?,
                "kc" => t.dgemm_kc = num()?,
                "nc" => t.dgemm_nc = num()?,
                "batch" => t.max_batch = num()?,
                "coalesce" => t.coalesce_budget_ns = num()? as u64,
                other => {
                    return Err(Error::Invalid(format!("unknown tuning key {other:?}")));
                }
            }
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_historical_constants() {
        let t = Tuning::default();
        assert_eq!(t.grain, 4096);
        assert_eq!(t.chunks_per_worker, 4);
        assert_eq!(t.dgemm_mc, 128);
        assert_eq!(t.dgemm_kc, 256);
        assert_eq!(t.dgemm_nc, 512);
        assert_eq!(t.max_batch, 32);
        assert_eq!(t.seg_path, SegPath::Auto);
        assert_eq!(t.to_kv(), "-");
    }

    #[test]
    fn kv_round_trip() {
        let t = Tuning {
            grain: 1024,
            chunks_per_worker: 8,
            pooled_cutoff: 9000,
            seg_path: SegPath::Runs,
            dgemm_mc: 64,
            dgemm_kc: 128,
            dgemm_nc: 256,
            max_batch: 16,
            coalesce_budget_ns: 5000,
        };
        let kv = t.to_kv();
        assert_eq!(Tuning::from_kv(&kv).unwrap(), t);
        assert_eq!(Tuning::from_kv("-").unwrap(), Tuning::default());
        assert_eq!(Tuning::from_kv("seg=fused").unwrap().seg_path, SegPath::Fused);
    }

    #[test]
    fn kv_rejects_garbage() {
        assert!(Tuning::from_kv("grain=abc").is_err());
        assert!(Tuning::from_kv("nonsense=1").is_err());
        assert!(Tuning::from_kv("grain").is_err());
        assert!(SegPath::parse("speedy").is_err());
    }
}
