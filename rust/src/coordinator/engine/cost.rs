//! Calibrated per-opcode-class cost model for the plan explorer.
//!
//! ArBB's capture-time optimiser chooses lowerings with a machine model
//! baked into the JIT; here the model is *measured*: at first use (or
//! when the plan store has no calibration for the active backend) each
//! [`OpClass`](profile::OpClass) is micro-timed against the real backend
//! kernels on `BLOCK`-sized buffers, and the resulting ns/element table
//! scores candidate plans in [`passes::explore`](crate::coordinator::passes::explore).
//!
//! The calibration reuses the [`crate::obs::profile`] opcode taxonomy and
//! accumulator, so estimated costs and runtime [`PlanProfile`]
//! (crate::obs::profile::PlanProfile) measurements are directly
//! comparable class by class — that comparison is what drives the
//! serve-side drift check.

use std::hint::black_box;
use std::time::Instant;

use super::backend::{self, Backend};
use super::tuning::BLOCK;
use crate::coordinator::ops::{BinOp, RedOp, UnOp};
use crate::coordinator::shape::View;
use crate::obs::profile::{OpClass, ProfileTable, N_CLASSES};

/// Repetitions per primitive during calibration — enough to amortise the
/// timer, small enough to keep first-use calibration well under a
/// millisecond per class.
const REPS: usize = 8;

/// Synthetic segmented workload used to calibrate the three spmv paths:
/// `SEG_ROWS` segments of `SEG_NNZ` non-zeros each.
const SEG_ROWS: usize = 128;
const SEG_NNZ: usize = 16;

/// Floor for a class that calibration could not measure (or that a
/// loaded store recorded as zero): prevents a zero-cost class from
/// making every candidate plan look free.
const FLOOR_NS_PER_ELEM: f64 = 0.05;

/// Measured ns-per-element for every opcode class on one backend.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Backend the constants were measured on (`scalar`, `avx2`, ...).
    pub backend: &'static str,
    /// ns/element indexed by `OpClass as usize`.
    pub ns_per_elem: [f64; N_CLASSES],
    /// Wall seconds the calibration pass took (0 when loaded from the
    /// plan store).
    pub calib_secs: f64,
}

impl CostModel {
    /// Rebuild a model from persisted constants (plan-store warm start).
    pub fn from_parts(backend: &'static str, ns_per_elem: [f64; N_CLASSES]) -> Self {
        CostModel { backend, ns_per_elem, calib_secs: 0.0 }
    }

    /// ns/element for one class, floored so estimates never hit zero.
    #[inline]
    pub fn ns_for(&self, c: OpClass) -> f64 {
        self.ns_per_elem[c as usize].max(FLOOR_NS_PER_ELEM)
    }

    /// Estimated ns/element of a fused tape given its per-class
    /// instruction histogram (each instruction touches every element of
    /// the block, so class costs are additive).
    pub fn tape_ns_per_elem(&self, hist: &[u32; N_CLASSES]) -> f64 {
        let mut ns = 0.0;
        for (ix, &count) in hist.iter().enumerate() {
            if count > 0 {
                let c = ns_index_class(ix);
                ns += count as f64 * self.ns_for(c);
            }
        }
        ns
    }

    /// Estimated ns for a segmented reduction over `nnz` total
    /// non-zeros on the given path class (`SegBlocked`/`SegFused`/
    /// `SegRuns`/`SpmvSerial`).
    pub fn seg_ns(&self, path: OpClass, nnz: usize) -> f64 {
        nnz as f64 * self.ns_for(path)
    }

    /// Estimated seconds for an `m x k * k x n` panel-blocked dgemm with
    /// row-panel height `mc` on `workers` threads. The inner loop is a
    /// `mul_add` stream over `m*k*n` elements; parallel speedup is
    /// capped by the number of row panels actually available.
    pub fn dgemm_secs(&self, m: usize, k: usize, n: usize, mc: usize, workers: usize) -> f64 {
        let work_ns = (m * k * n) as f64 * self.ns_for(OpClass::MulAdd);
        let panels = m.div_ceil(mc.max(1)).max(1);
        let eff = workers.min(panels).max(1) as f64;
        // Per-panel fork/join + packing overhead: one pass over the
        // panel's inputs at contiguous-load cost.
        let over_ns = panels as f64 * (mc.min(m) * k) as f64 * self.ns_for(OpClass::LoadContiguous);
        work_ns / eff + over_ns
    }

    /// Measure every class against `bk`'s real kernels.
    pub fn calibrate(bk: &'static dyn Backend) -> CostModel {
        let t0 = Instant::now();
        let table = ProfileTable::new();

        let n = BLOCK;
        let a: Vec<f64> = (0..n).map(|i| 1.0 + (i % 97) as f64 * 1e-3).collect();
        let b: Vec<f64> = (0..n).map(|i| 0.5 + (i % 89) as f64 * 1e-3).collect();
        let ix: Vec<i64> = (0..n).map(|i| ((i * 7) % n) as i64).collect();
        let mut out = vec![0.0f64; n];

        let mut time = |c: OpClass, elems: usize, f: &mut dyn FnMut()| {
            f(); // warm-up (page in buffers, prime the branch predictor)
            let t = Instant::now();
            for _ in 0..REPS {
                f();
            }
            let ns = t.elapsed().as_nanos() as u64;
            table.record(c, (elems * REPS) as u64, ns.max(1));
        };

        // ---- loaders -----------------------------------------------
        time(OpClass::LoadContiguous, n, &mut || {
            backend::load_contiguous(&a, 0, 0, &mut out);
            black_box(&out);
        });
        time(OpClass::LoadSplat, n, &mut || {
            out.fill(black_box(1.5));
            black_box(&out);
        });
        let bview = View { base: 0, row_stride: 1, col_stride: 0, out_cols: 64, modulo: None };
        time(OpClass::LoadBroadcast, n, &mut || {
            backend::load_broadcast(&a, &bview, 0, &mut out);
            black_box(&out);
        });
        let sview = View { base: 0, row_stride: 64, col_stride: 1, out_cols: 64, modulo: None };
        time(OpClass::LoadStrided, n, &mut || {
            backend::load_strided(&a, &sview, 0, &mut out);
            black_box(&out);
        });
        let mview = View { base: 0, row_stride: 0, col_stride: 1, out_cols: n, modulo: Some(64) };
        time(OpClass::LoadModulo, n, &mut || {
            backend::load_modulo(&a, &mview, 0, &mut out);
            black_box(&out);
        });
        time(OpClass::LoadGather, n, &mut || {
            bk.load_gather(&mut out, &a, &ix);
            black_box(&out);
        });
        time(OpClass::LoadConst, n, &mut || {
            out.fill(black_box(0.0));
            black_box(&out);
        });
        time(OpClass::LoadIota, n, &mut || {
            for (i, o) in out.iter_mut().enumerate() {
                *o = i as f64;
            }
            black_box(&out);
        });

        // ---- element-wise ------------------------------------------
        time(OpClass::Bin, n, &mut || {
            bk.bin_inplace(BinOp::Add, &mut out, &b);
            black_box(&out);
        });
        time(OpClass::BinConst, n, &mut || {
            bk.bin_scalar_inplace(BinOp::Mul, &mut out, black_box(1.0000001));
            black_box(&out);
        });
        // BinSplat lowers to the same scalar-broadcast kernel.
        time(OpClass::BinSplat, n, &mut || {
            bk.bin_scalar_inplace(BinOp::Add, &mut out, black_box(1e-9));
            black_box(&out);
        });
        out.copy_from_slice(&a);
        time(OpClass::Un, n, &mut || {
            bk.un_inplace(UnOp::Abs, &mut out);
            black_box(&out);
        });
        time(OpClass::MulAdd, n, &mut || {
            bk.mul_add(&mut out, &a, &b);
            black_box(&out);
        });
        time(OpClass::MulSub, n, &mut || {
            bk.mul_sub(&mut out, &a, &b);
            black_box(&out);
        });
        time(OpClass::ScaleAddConst, n, &mut || {
            bk.scale_add_const(&mut out, black_box(1.0000001), black_box(1e-9));
            black_box(&out);
        });
        time(OpClass::Axpy, n, &mut || {
            bk.axpy_update(black_box(1e-9), &mut out, &b);
            black_box(&out);
        });

        // ---- reductions --------------------------------------------
        time(OpClass::Fold, n, &mut || {
            black_box(bk.fold_slice(RedOp::Sum, &a));
        });
        time(OpClass::Dot, n, &mut || {
            bk.mul_streams(&mut out, &a, &b);
            black_box(bk.fold_slice(RedOp::Sum, &out));
        });

        // ---- segmented spmv paths ----------------------------------
        // One synthetic banded matrix, timed through the exact inner
        // kernels each SegTape path dispatches per row.
        let nnz = SEG_ROWS * SEG_NNZ;
        let vals: Vec<f64> = (0..nnz).map(|i| 1.0 + (i % 13) as f64 * 0.01).collect();
        let x: Vec<f64> = (0..n).map(|i| 0.25 + (i % 31) as f64 * 0.01).collect();
        // Gathered (scattered) column indices for blocked/fused; the
        // runs path sees each row as one contiguous stream.
        let gidx: Vec<i64> = (0..nnz).map(|i| ((i * 11) % n) as i64).collect();
        let mut rowbuf = vec![0.0f64; SEG_NNZ];

        time(OpClass::SpmvSerial, nnz, &mut || {
            let mut acc = 0.0;
            for r in 0..SEG_ROWS {
                let s = r * SEG_NNZ;
                acc += backend::spmv_row_serial(&vals, &gidx, &x, s, s + SEG_NNZ);
            }
            black_box(acc);
        });
        time(OpClass::SegFused, nnz, &mut || {
            let mut acc = 0.0;
            for r in 0..SEG_ROWS {
                let s = r * SEG_NNZ;
                acc += bk.gather_mul_sum(&vals[s..s + SEG_NNZ], &x, &gidx[s..s + SEG_NNZ]);
            }
            black_box(acc);
        });
        time(OpClass::SegRuns, nnz, &mut || {
            let mut acc = 0.0;
            for r in 0..SEG_ROWS {
                let s = r * SEG_NNZ;
                let xs = (r * 29) % (n - SEG_NNZ);
                bk.mul_streams(&mut rowbuf, &vals[s..s + SEG_NNZ], &x[xs..xs + SEG_NNZ]);
                acc = bk.fold_segment_chunk(RedOp::Sum, acc, &rowbuf);
            }
            black_box(acc);
        });
        time(OpClass::SegBlocked, nnz, &mut || {
            // blocked = tape-fill (gather + multiply) then segment fold
            let mut acc = 0.0;
            for r in 0..SEG_ROWS {
                let s = r * SEG_NNZ;
                bk.load_gather(&mut rowbuf, &x, &gidx[s..s + SEG_NNZ]);
                bk.bin_inplace(BinOp::Mul, &mut rowbuf, &vals[s..s + SEG_NNZ]);
                acc = bk.fold_segment_chunk(RedOp::Sum, acc, &rowbuf);
            }
            black_box(acc);
        });

        let snap = table.snapshot(bk.name());
        let mut ns_per_elem = [0.0f64; N_CLASSES];
        for (ix, st) in snap.classes.iter().enumerate() {
            ns_per_elem[ix] = st.ns_per_elem();
        }
        CostModel { backend: bk.name(), ns_per_elem, calib_secs: t0.elapsed().as_secs_f64() }
    }
}

/// Recover the `OpClass` for an `as usize` index (histograms are indexed
/// arrays; this is the inverse used when walking them).
fn ns_index_class(ix: usize) -> OpClass {
    use OpClass::*;
    const ALL: [OpClass; N_CLASSES] = [
        LoadContiguous,
        LoadSplat,
        LoadBroadcast,
        LoadStrided,
        LoadModulo,
        LoadGather,
        LoadConst,
        LoadIota,
        Bin,
        BinConst,
        BinSplat,
        Un,
        MulAdd,
        MulSub,
        ScaleAddConst,
        Axpy,
        Fold,
        SegBlocked,
        SegFused,
        SegRuns,
        SpmvSerial,
        Dot,
    ];
    ALL[ix]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_covers_every_class() {
        let cm = CostModel::calibrate(backend::select(backend::BackendSel::Scalar));
        assert_eq!(cm.backend, "scalar");
        for (ix, &ns) in cm.ns_per_elem.iter().enumerate() {
            assert!(ns > 0.0, "class {ix} not calibrated");
            assert!(ns < 1e6, "class {ix} implausible: {ns} ns/elem");
        }
        assert!(cm.calib_secs > 0.0);
    }

    #[test]
    fn tape_estimate_is_additive() {
        let mut ns = [1.0f64; N_CLASSES];
        ns[OpClass::Bin as usize] = 2.0;
        let cm = CostModel::from_parts("scalar", ns);
        let mut h = [0u32; N_CLASSES];
        h[OpClass::Bin as usize] = 3;
        h[OpClass::LoadContiguous as usize] = 1;
        assert!((cm.tape_ns_per_elem(&h) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn dgemm_model_prefers_smaller_panels_when_underutilised() {
        // m=256, MC=128 gives only 2 panels for 4 workers; MC=64 gives 4.
        let cm = CostModel::from_parts("scalar", [1.0; N_CLASSES]);
        let big = cm.dgemm_secs(256, 256, 256, 128, 4);
        let small = cm.dgemm_secs(256, 256, 256, 64, 4);
        assert!(small < big, "MC=64 ({small}) should beat MC=128 ({big})");
    }

    #[test]
    fn zero_entries_are_floored() {
        let cm = CostModel::from_parts("scalar", [0.0; N_CLASSES]);
        assert!(cm.ns_for(OpClass::Bin) > 0.0);
    }
}
