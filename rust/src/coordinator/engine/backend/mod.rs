//! Backend kernel layer: every per-block compute kernel of the runtime,
//! behind a runtime-selected [`Backend`].
//!
//! ArBB's JIT emits SSE/AVX code per target ISA from one data-parallel
//! source (§2 of the paper: "the vectorizer generates code for the SIMD
//! units"). This reproduction's analogue is this module: the block
//! kernels the tape VM, the segmented executor, the program replayer
//! and the serving arena replay all share — leaf loaders, element-wise
//! operator passes, the fused superinstructions (`MulAdd`, `Axpy`,
//! `ScaleAddConst`), reduction folds and the fused spmv inner loop —
//! are trait methods dispatched once per ≤[`BLOCK`]-element block, so a
//! single compiled tape retargets to whatever vector width the backend
//! provides.
//!
//! Two backends ship today:
//!
//!  * [`ScalarBackend`] — the trait's default bodies: the reference
//!    kernels extracted verbatim from the pre-backend executors, so
//!    scalar results are bit-stable across the refactor.
//!  * `Avx2Backend` (x86-64 only, behind runtime
//!    `is_x86_feature_detected!`) — explicit AVX2 `f64x4` kernels with
//!    scalar tails. No FMA contraction, ever: fusing the rounding step
//!    would break bit-equality with the scalar reference.
//!
//! # The association contract
//!
//! Element-wise kernels are trivially bit-identical across backends
//! (IEEE-754 lane arithmetic does not care about width). Reductions are
//! bit-identical **by construction**: the canonical order is the 4-lane
//! unroll of [`RedOp::fold_slice`] — lane `j` accumulates elements
//! `j, j+4, j+8, …` of a chunk, lanes merge as `((l0+l1)+l2)+l3`, the
//! remainder folds serially, and per-segment chunks merge through
//! [`Backend::fold_segment_chunk`]. A SIMD sum that keeps one `f64x4`
//! accumulator vector *is* that order, so every backend must implement
//! [`Backend::fold_slice`] and [`Backend::gather_mul_sum`] in exactly
//! this association (asserted bitwise by `rust/tests/tape_vs_tree.rs`
//! and the segmented property suite across forced backends).
//!
//! Selection happens once per process for [`active`] (the
//! `PALLAS_BACKEND=scalar|avx2` environment override, else the best
//! detected ISA) and per [`crate::coordinator::Context`] through
//! [`BackendSel`] in [`crate::coordinator::Options`].
//!
//! [`BLOCK`]: crate::coordinator::engine::eval::BLOCK

use std::fmt;
use std::sync::OnceLock;

use crate::coordinator::ops::{BinOp, RedOp, UnOp};
use crate::coordinator::shape::View;

#[cfg(target_arch = "x86_64")]
mod avx2;

/// The per-block kernel vocabulary. Default method bodies are the
/// scalar reference implementations; a SIMD backend overrides the
/// kernels it accelerates and inherits the rest (NaN-sensitive `Min`/
/// `Max` and the libm-backed `Exp`/`Ln` stay scalar everywhere so the
/// bit contract holds without reimplementing libm).
///
/// All methods operate on one evaluation block (≤ a few KiB), so the
/// virtual dispatch amortises to noise against the inner loops.
pub trait Backend: Send + Sync + fmt::Debug {
    /// Stable name for stats, bench records and diagnostics.
    fn name(&self) -> &'static str;

    // ---- element-wise operator kernels ------------------------------

    /// `acc[i] = op(acc[i], rhs[i])`.
    fn bin_inplace(&self, op: BinOp, acc: &mut [f64], rhs: &[f64]) {
        op.apply_slices_inplace(acc, rhs);
    }

    /// `out[i] = op(out[i], s)` (scalar right operand; `Div` multiplies
    /// by the reciprocal, computed once — part of the bit contract).
    fn bin_scalar_inplace(&self, op: BinOp, out: &mut [f64], s: f64) {
        op.apply_slice_scalar_inplace(out, s);
    }

    /// `out[i] = op(out[i])`.
    fn un_inplace(&self, op: UnOp, out: &mut [f64]) {
        op.apply_slice_inplace(out);
    }

    /// `dst[i] += a[i] * b[i]` — the `MulAdd` superinstruction. One
    /// multiply rounding, one add rounding per element (no FMA).
    fn mul_add(&self, dst: &mut [f64], a: &[f64], b: &[f64]) {
        debug_assert!(a.len() >= dst.len() && b.len() >= dst.len());
        for i in 0..dst.len() {
            dst[i] += a[i] * b[i];
        }
    }

    /// `dst[i] -= a[i] * b[i]` — the `MulSub` superinstruction.
    fn mul_sub(&self, dst: &mut [f64], a: &[f64], b: &[f64]) {
        debug_assert!(a.len() >= dst.len() && b.len() >= dst.len());
        for i in 0..dst.len() {
            dst[i] -= a[i] * b[i];
        }
    }

    /// `out[i] = a[i] * b[i]` — the product-stream kernel of the
    /// contiguity-run spmv path.
    fn mul_streams(&self, out: &mut [f64], a: &[f64], b: &[f64]) {
        debug_assert!(a.len() >= out.len() && b.len() >= out.len());
        for i in 0..out.len() {
            out[i] = a[i] * b[i];
        }
    }

    /// `dst[i] = dst[i] * mul + add` — the `ScaleAddConst` peephole.
    fn scale_add_const(&self, dst: &mut [f64], mul: f64, add: f64) {
        for x in dst.iter_mut() {
            *x = *x * mul + add;
        }
    }

    /// `dst[i] += f * src[i]` — the per-segment inner op of the rank-1
    /// `Axpy` superinstruction (`f` carries the sign for subtraction).
    fn axpy_update(&self, f: f64, dst: &mut [f64], src: &[f64]) {
        debug_assert!(src.len() >= dst.len());
        for i in 0..dst.len() {
            dst[i] += f * src[i];
        }
    }

    // ---- loaders ----------------------------------------------------

    /// `out[k] = src[ix[k]]` — the monomorphised gather loader (index
    /// tables are pre-validated, see `fexec_to_ktree`/`audit_gathers`).
    fn load_gather(&self, out: &mut [f64], src: &[f64], ix: &[i64]) {
        debug_assert!(ix.len() >= out.len());
        for (o, &i) in out.iter_mut().zip(ix) {
            *o = src[i as usize];
        }
    }

    // ---- reductions: the 4-lane association contract ----------------

    /// Reduce one chunk. Must reproduce [`RedOp::fold_slice`] — the
    /// 4-lane unrolled association for `Sum` — bit for bit.
    fn fold_slice(&self, red: RedOp, xs: &[f64]) -> f64 {
        red.fold_slice(xs)
    }

    /// Merge one ≤BLOCK chunk of segment values into a running segment
    /// accumulator: the association contract every segmented executor
    /// shares (see [`RedOp::fold_segment_chunk`]).
    fn fold_segment_chunk(&self, red: RedOp, acc: f64, chunk: &[f64]) -> f64 {
        red.fold(acc, self.fold_slice(red, chunk))
    }

    /// One chunk of the fused spmv inner loop:
    /// `Σ vals[t] · x[ix[t]]` over the chunk, in exactly the 4-lane
    /// association of [`RedOp::fold_slice`] for `Sum`, so the fused
    /// path stays bit-identical to tape-fill + [`Self::fold_slice`].
    fn gather_mul_sum(&self, vals: &[f64], x: &[f64], ix: &[i64]) -> f64 {
        debug_assert_eq!(vals.len(), ix.len());
        let l = vals.len();
        let m4 = l - (l % 4);
        let mut a = [0.0f64; 4];
        let mut t = 0;
        while t < m4 {
            a[0] += vals[t] * x[ix[t] as usize];
            a[1] += vals[t + 1] * x[ix[t + 1] as usize];
            a[2] += vals[t + 2] * x[ix[t + 2] as usize];
            a[3] += vals[t + 3] * x[ix[t + 3] as usize];
            t += 4;
        }
        let mut s = a[0] + a[1] + a[2] + a[3];
        while t < l {
            s += vals[t] * x[ix[t] as usize];
            t += 1;
        }
        s
    }
}

/// The scalar reference backend: every kernel is the trait's default
/// body — the code extracted verbatim from the pre-backend executors —
/// so results are bit-stable across the refactor and across ISAs.
#[derive(Debug)]
pub struct ScalarBackend;

impl Backend for ScalarBackend {
    fn name(&self) -> &'static str {
        "scalar"
    }
}

static SCALAR: ScalarBackend = ScalarBackend;

/// The scalar reference backend (always available).
pub fn scalar() -> &'static dyn Backend {
    &SCALAR
}

/// The SIMD backend for this machine, if the ISA is present: AVX2 on
/// x86-64 (detected once at first call), `None` elsewhere.
#[cfg(target_arch = "x86_64")]
pub fn simd() -> Option<&'static dyn Backend> {
    static AVX2_OK: OnceLock<bool> = OnceLock::new();
    if *AVX2_OK.get_or_init(|| std::arch::is_x86_feature_detected!("avx2")) {
        Some(avx2::backend())
    } else {
        None
    }
}

/// The SIMD backend for this machine, if the ISA is present (non-x86:
/// none yet — the seam is where an AVX-512 or NEON backend plugs in).
#[cfg(not(target_arch = "x86_64"))]
pub fn simd() -> Option<&'static dyn Backend> {
    None
}

/// Per-context backend selection, carried by
/// [`crate::coordinator::Options`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum BackendSel {
    /// The process-wide [`active`] backend: `PALLAS_BACKEND` override
    /// if set, else the best detected ISA.
    #[default]
    Auto,
    /// Force the scalar reference kernels.
    Scalar,
    /// Force the SIMD kernels; falls back to scalar when the ISA is
    /// absent (so a forced-SIMD config is portable).
    Simd,
}

/// Resolve a selection to a backend.
pub fn select(sel: BackendSel) -> &'static dyn Backend {
    match sel {
        BackendSel::Auto => active(),
        BackendSel::Scalar => scalar(),
        BackendSel::Simd => simd().unwrap_or_else(scalar),
    }
}

/// The process-wide active backend, chosen once at first use:
/// `PALLAS_BACKEND=scalar` forces the reference kernels (the CI
/// fallback leg), `PALLAS_BACKEND=avx2` (or `simd`) requests the SIMD
/// kernels. An *unrecognised* name is rejected loudly (logged, then the
/// best detected ISA is used) instead of being silently treated as
/// auto-detect; a recognised but undetected ISA falls back to scalar
/// rather than faulting, so a forced-SIMD config stays portable.
pub fn active() -> &'static dyn Backend {
    static ACTIVE: OnceLock<&'static dyn Backend> = OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var("PALLAS_BACKEND") {
        Ok(name) => match parse_backend(&name) {
            Ok(BackendSel::Scalar) => scalar(),
            Ok(BackendSel::Simd) | Ok(BackendSel::Auto) => simd().unwrap_or_else(scalar),
            Err(why) => {
                eprintln!("arbb: ignoring PALLAS_BACKEND={name:?}: {why}; auto-detecting");
                simd().unwrap_or_else(scalar)
            }
        },
        Err(_) => simd().unwrap_or_else(scalar),
    })
}

/// Strict `PALLAS_BACKEND` parser. Recognised names: `scalar`, `avx2`,
/// `simd`, `auto`. Anything else is an error naming the valid set (no
/// silent fallback — [`active`] logs the rejection).
pub(crate) fn parse_backend(raw: &str) -> std::result::Result<BackendSel, String> {
    match raw.trim() {
        "scalar" => Ok(BackendSel::Scalar),
        "avx2" | "simd" => Ok(BackendSel::Simd),
        "auto" | "" => Ok(BackendSel::Auto),
        other => Err(format!("unknown backend {other:?} (expected scalar|avx2|simd|auto)")),
    }
}

// ---------------------------------------------------------------------
// Shared leaf loaders (memory movement, no float arithmetic)
// ---------------------------------------------------------------------
//
// One function per affine view shape, classified once at tape-compile
// time; the reference tree interpreter's `fill_view` re-classifies per
// block and dispatches to the same loaders, keeping every executor
// bit-exact. Pure data movement reorders nothing, so these are shared
// across backends rather than trait methods.

/// Contiguous leaf: a single memcpy.
#[inline]
pub fn load_contiguous(data: &[f64], base: usize, start: usize, out: &mut [f64]) {
    let s = base + start;
    out.copy_from_slice(&data[s..s + out.len()]);
}

/// Column-broadcast leaf (`col_stride == 0`, no modulo): one constant
/// fill per output-row segment.
#[inline]
pub fn load_broadcast(data: &[f64], view: &View, start: usize, out: &mut [f64]) {
    let oc = view.out_cols.max(1);
    let len = out.len();
    let mut pos = 0usize;
    let mut r = start / oc;
    let mut c = start % oc;
    while pos < len {
        let seg = (oc - c).min(len - pos);
        out[pos..pos + seg].fill(data[view.base + r * view.row_stride]);
        pos += seg;
        r += 1;
        c = 0;
    }
}

/// Strided leaf (`col_stride >= 1`, no modulo): unit-stride row segments
/// memcpy, otherwise a strided gather per segment.
#[inline]
pub fn load_strided(data: &[f64], view: &View, start: usize, out: &mut [f64]) {
    let oc = view.out_cols.max(1);
    let len = out.len();
    let cs = view.col_stride;
    let mut pos = 0usize;
    let mut r = start / oc;
    let mut c = start % oc;
    while pos < len {
        let seg = (oc - c).min(len - pos);
        let s0 = view.base + r * view.row_stride + c * cs;
        let o = &mut out[pos..pos + seg];
        if cs == 1 {
            o.copy_from_slice(&data[s0..s0 + seg]);
        } else {
            let mut s = s0;
            for x in o.iter_mut() {
                *x = data[s];
                s += cs;
            }
        }
        pos += seg;
        r += 1;
        c = 0;
    }
}

/// Cyclic leaf (`repeat` views): wrap by subtraction — col_stride never
/// exceeds the period by construction (compose scales both).
#[inline]
pub fn load_modulo(data: &[f64], view: &View, start: usize, out: &mut [f64]) {
    let oc = view.out_cols.max(1);
    let len = out.len();
    let cs = view.col_stride;
    let m = match view.modulo {
        Some(m) => m,
        None => return,
    };
    let mut pos = 0usize;
    let mut r = start / oc;
    let mut c = start % oc;
    while pos < len {
        let seg = (oc - c).min(len - pos);
        let mut lin = (r * view.row_stride + c * cs) % m;
        for x in out[pos..pos + seg].iter_mut() {
            *x = data[view.base + lin];
            lin += cs;
            if lin >= m {
                lin %= m;
            }
        }
        pos += seg;
        r += 1;
        c = 0;
    }
}

/// Gather a block through an affine view: classify the view shape and
/// dispatch to the matching monomorphised loader.
pub fn fill_view(data: &[f64], view: &View, start: usize, out: &mut [f64]) {
    if view.is_contiguous() {
        load_contiguous(data, view.base, start, out);
    } else if view.modulo.is_some() {
        load_modulo(data, view, start, out);
    } else if view.col_stride == 0 {
        load_broadcast(data, view, start, out);
    } else {
        load_strided(data, view, start, out);
    }
}

/// Rank-1 update (`Axpy`): `out[seg] op= a_r * b[seg]` per output-row
/// segment, with `a` a column-broadcast leaf and `b` a unit-stride row
/// leaf (possibly cyclic). The segment walk is shared; the inner
/// per-segment update goes through [`Backend::axpy_update`].
pub fn axpy_pattern(
    bk: &dyn Backend,
    op: BinOp,
    da: &[f64],
    va: &View,
    db: &[f64],
    vb: &View,
    start: usize,
    out: &mut [f64],
) {
    let oc = va.out_cols.max(1);
    let len = out.len();
    let mut pos = 0usize;
    let mut r = start / oc;
    let mut c = start % oc;
    while pos < len {
        let seg = (oc - c).min(len - pos);
        let f = da[va.base + r * va.row_stride];
        let f = if op == BinOp::Sub { -f } else { f };
        // source segment through vb (cs == 1), splitting at cyclic wraps
        let mut done = 0usize;
        while done < seg {
            let lin = r * vb.row_stride + (c + done);
            let (off, room) = match vb.modulo {
                Some(m) => (lin % m, m - lin % m),
                None => (lin, usize::MAX),
            };
            let take = room.min(seg - done);
            let src = &db[vb.base + off..vb.base + off + take];
            let dst = &mut out[pos + done..pos + done + take];
            bk.axpy_update(f, dst, src);
            done += take;
        }
        pos += seg;
        r += 1;
        c = 0;
    }
}

/// Serial CSR row dot: `Σ vals[k] · x[indx[k]]` over `k ∈ [s, e)` in
/// strict left-to-right order — the **host** association contract shared
/// by [`crate::sparse::Csr::spmv`] and the captured-program spmv step
/// (which must stay bit-identical to the host solver, not to the tape's
/// 4-lane contract). Deliberately not a [`Backend`] method: no backend
/// may reorder it.
#[inline]
pub fn spmv_row_serial(vals: &[f64], indx: &[i64], x: &[f64], s: usize, e: usize) -> f64 {
    let mut acc = 0.0;
    for k in s..e {
        acc += vals[k] * x[indx[k] as usize];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift64;

    fn rand_vec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = XorShift64::new(seed);
        (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect()
    }

    #[test]
    fn backend_env_parser_is_strict() {
        assert!(matches!(parse_backend("scalar"), Ok(BackendSel::Scalar)));
        assert!(matches!(parse_backend("avx2"), Ok(BackendSel::Simd)));
        assert!(matches!(parse_backend(" simd "), Ok(BackendSel::Simd)));
        assert!(matches!(parse_backend("auto"), Ok(BackendSel::Auto)));
        assert!(parse_backend("sse9").is_err());
        assert!(parse_backend("AVX2").is_err());
    }

    /// Pairs of backends to cross-check (scalar vs SIMD when present).
    fn pairs() -> Vec<(&'static dyn Backend, &'static dyn Backend)> {
        match simd() {
            Some(s) => vec![(scalar(), s)],
            None => vec![(scalar(), scalar())],
        }
    }

    #[test]
    fn selection_resolves() {
        assert_eq!(select(BackendSel::Scalar).name(), "scalar");
        let auto = select(BackendSel::Auto);
        let simd_bk = select(BackendSel::Simd);
        // Auto and Simd agree unless the env override forces scalar.
        if std::env::var("PALLAS_BACKEND").as_deref() != Ok("scalar") {
            assert_eq!(auto.name(), simd_bk.name());
        }
    }

    #[test]
    fn elementwise_kernels_bit_identical() {
        // Odd length exercises the SIMD tails.
        let n = 1027;
        let a0 = rand_vec(n, 1);
        let b = rand_vec(n, 2);
        for (r, s) in pairs() {
            for op in [BinOp::Add, BinOp::Sub, BinOp::Mul, BinOp::Div, BinOp::Min, BinOp::Max] {
                let mut x = a0.clone();
                let mut y = a0.clone();
                r.bin_inplace(op, &mut x, &b);
                s.bin_inplace(op, &mut y, &b);
                assert!(bits_eq(&x, &y), "bin_inplace {op:?}");
                let mut x = a0.clone();
                let mut y = a0.clone();
                r.bin_scalar_inplace(op, &mut x, 0.37);
                s.bin_scalar_inplace(op, &mut y, 0.37);
                assert!(bits_eq(&x, &y), "bin_scalar_inplace {op:?}");
            }
            for op in [UnOp::Neg, UnOp::Abs, UnOp::Sqrt, UnOp::Exp, UnOp::Ln, UnOp::Recip] {
                let mut x = a0.clone();
                let mut y = a0.clone();
                r.un_inplace(op, &mut x);
                s.un_inplace(op, &mut y);
                assert!(bits_eq(&x, &y), "un_inplace {op:?}");
            }
            let (mut x, mut y) = (a0.clone(), a0.clone());
            r.mul_add(&mut x, &b, &a0);
            s.mul_add(&mut y, &b, &a0);
            assert!(bits_eq(&x, &y), "mul_add");
            let (mut x, mut y) = (a0.clone(), a0.clone());
            r.mul_sub(&mut x, &b, &a0);
            s.mul_sub(&mut y, &b, &a0);
            assert!(bits_eq(&x, &y), "mul_sub");
            let (mut x, mut y) = (vec![0.0; n], vec![0.0; n]);
            r.mul_streams(&mut x, &a0, &b);
            s.mul_streams(&mut y, &a0, &b);
            assert!(bits_eq(&x, &y), "mul_streams");
            let (mut x, mut y) = (a0.clone(), a0.clone());
            r.scale_add_const(&mut x, 1.25, -0.5);
            s.scale_add_const(&mut y, 1.25, -0.5);
            assert!(bits_eq(&x, &y), "scale_add_const");
            let (mut x, mut y) = (a0.clone(), a0.clone());
            r.axpy_update(-0.75, &mut x, &b);
            s.axpy_update(-0.75, &mut y, &b);
            assert!(bits_eq(&x, &y), "axpy_update");
        }
    }

    #[test]
    fn reductions_bit_identical() {
        for n in [0usize, 1, 3, 4, 5, 257, 2048, 2049] {
            let xs = rand_vec(n, 90 + n as u64);
            for (r, s) in pairs() {
                for red in [RedOp::Sum, RedOp::Prod, RedOp::Min, RedOp::Max] {
                    let a = r.fold_slice(red, &xs);
                    let b = s.fold_slice(red, &xs);
                    assert_eq!(a.to_bits(), b.to_bits(), "fold_slice {red:?} n={n}");
                    // And both must equal the canonical contract.
                    assert_eq!(a.to_bits(), red.fold_slice(&xs).to_bits());
                }
            }
        }
    }

    #[test]
    fn gather_kernels_bit_identical() {
        let mut rng = XorShift64::new(7);
        for n in [0usize, 1, 5, 1023, 4096] {
            let src = rand_vec(97, n as u64 + 3);
            let ix: Vec<i64> = (0..n).map(|_| rng.below(97) as i64).collect();
            let vals = rand_vec(n, n as u64 + 11);
            for (r, s) in pairs() {
                let mut x = vec![0.0; n];
                let mut y = vec![1.0; n];
                r.load_gather(&mut x, &src, &ix);
                s.load_gather(&mut y, &src, &ix);
                assert!(bits_eq(&x, &y), "load_gather n={n}");
                let a = r.gather_mul_sum(&vals, &src, &ix);
                let b = s.gather_mul_sum(&vals, &src, &ix);
                assert_eq!(a.to_bits(), b.to_bits(), "gather_mul_sum n={n}");
            }
        }
    }

    fn bits_eq(a: &[f64], b: &[f64]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan())
            })
    }
}
