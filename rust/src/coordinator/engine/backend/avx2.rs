//! AVX2 `f64x4` kernels (x86-64 only, runtime-detected).
//!
//! Every kernel is bit-identical to the scalar reference by
//! construction:
//!
//!  * element-wise kernels perform the same IEEE-754 operations per
//!    lane — width does not change rounding — and **never** contract
//!    multiply+add into FMA (one fused rounding would diverge);
//!  * reductions keep one 4-lane accumulator vector, which is exactly
//!    the 4-way unroll of [`RedOp::fold_slice`] (lane `j` accumulates
//!    elements `j, j+4, …`), merged `((l0+l1)+l2)+l3` with a serial
//!    tail — the canonical association contract;
//!  * NaN-sensitive `Min`/`Max` (x86 `vminpd` NaN semantics differ from
//!    `f64::min`) and libm-backed `Exp`/`Ln` delegate to the scalar
//!    kernels rather than approximate.
//!
//! The backend is only handed out by [`super::simd`] after
//! `is_x86_feature_detected!("avx2")`, which makes the
//! `#[target_feature(enable = "avx2")]` calls below sound.

#![cfg(target_arch = "x86_64")]

use std::arch::x86_64::*;

use super::Backend;
use crate::coordinator::ops::{BinOp, RedOp, UnOp};

/// The AVX2 backend (unit struct; selection is gated by detection).
#[derive(Debug)]
pub(super) struct Avx2Backend;

static AVX2: Avx2Backend = Avx2Backend;

/// The shared AVX2 backend instance. Callers must have verified AVX2
/// support ([`super::simd`] does).
pub(super) fn backend() -> &'static dyn Backend {
    &AVX2
}

impl Backend for Avx2Backend {
    fn name(&self) -> &'static str {
        "avx2"
    }

    fn bin_inplace(&self, op: BinOp, acc: &mut [f64], rhs: &[f64]) {
        // Hard assert, not debug: the vector loops below are bounded by
        // `acc.len()` with unchecked loads from `rhs` — a short `rhs`
        // must panic (as the scalar kernel's bounds checks do), never
        // read out of bounds.
        assert!(rhs.len() >= acc.len());
        match op {
            // SAFETY: construction is gated on AVX2 detection.
            BinOp::Add => unsafe { bin_add(acc, rhs) },
            BinOp::Sub => unsafe { bin_sub(acc, rhs) },
            BinOp::Mul => unsafe { bin_mul(acc, rhs) },
            BinOp::Div => unsafe { bin_div(acc, rhs) },
            // `vminpd`/`vmaxpd` NaN handling differs from `f64::min`:
            // keep the scalar kernel so the bit contract holds.
            BinOp::Min | BinOp::Max => op.apply_slices_inplace(acc, rhs),
        }
    }

    fn bin_scalar_inplace(&self, op: BinOp, out: &mut [f64], s: f64) {
        match op {
            // SAFETY: construction is gated on AVX2 detection.
            BinOp::Add => unsafe { bin_scalar_add(out, s) },
            // A true subtract, not `x + (-s)`: identical for every
            // finite s, but a NaN scalar must propagate its own sign
            // bit exactly as the scalar kernel's `x - s` does.
            BinOp::Sub => unsafe { bin_scalar_sub(out, s) },
            BinOp::Mul => unsafe { bin_scalar_mul(out, s) },
            // The scalar contract multiplies by the reciprocal,
            // computed once.
            BinOp::Div => unsafe { bin_scalar_mul(out, 1.0 / s) },
            BinOp::Min | BinOp::Max => op.apply_slice_scalar_inplace(out, s),
        }
    }

    fn un_inplace(&self, op: UnOp, out: &mut [f64]) {
        match op {
            // SAFETY: construction is gated on AVX2 detection.
            UnOp::Neg => unsafe { un_neg(out) },
            UnOp::Abs => unsafe { un_abs(out) },
            UnOp::Sqrt => unsafe { un_sqrt(out) },
            UnOp::Recip => unsafe { un_recip(out) },
            // libm calls: scalar everywhere, by contract.
            UnOp::Exp | UnOp::Ln => op.apply_slice_inplace(out),
        }
    }

    fn mul_add(&self, dst: &mut [f64], a: &[f64], b: &[f64]) {
        assert!(a.len() >= dst.len() && b.len() >= dst.len());
        // SAFETY: construction is gated on AVX2 detection.
        unsafe { mul_add(dst, a, b) }
    }

    fn mul_sub(&self, dst: &mut [f64], a: &[f64], b: &[f64]) {
        assert!(a.len() >= dst.len() && b.len() >= dst.len());
        // SAFETY: construction is gated on AVX2 detection.
        unsafe { mul_sub(dst, a, b) }
    }

    fn mul_streams(&self, out: &mut [f64], a: &[f64], b: &[f64]) {
        assert!(a.len() >= out.len() && b.len() >= out.len());
        // SAFETY: construction is gated on AVX2 detection.
        unsafe { mul_streams(out, a, b) }
    }

    fn scale_add_const(&self, dst: &mut [f64], mul: f64, add: f64) {
        // SAFETY: construction is gated on AVX2 detection.
        unsafe { scale_add_const(dst, mul, add) }
    }

    fn axpy_update(&self, f: f64, dst: &mut [f64], src: &[f64]) {
        assert!(src.len() >= dst.len());
        // SAFETY: construction is gated on AVX2 detection.
        unsafe { axpy_update(f, dst, src) }
    }

    fn fold_slice(&self, red: RedOp, xs: &[f64]) -> f64 {
        match red {
            // SAFETY: construction is gated on AVX2 detection.
            RedOp::Sum => unsafe { sum_slice(xs) },
            // Prod/Min/Max fold serially in the scalar contract; keep
            // the reference kernel.
            _ => red.fold_slice(xs),
        }
    }

    fn gather_mul_sum(&self, vals: &[f64], x: &[f64], ix: &[i64]) -> f64 {
        debug_assert_eq!(vals.len(), ix.len());
        // SAFETY: construction is gated on AVX2 detection.
        unsafe { gather_mul_sum(vals, x, ix) }
    }
}

// ---------------------------------------------------------------------
// Kernels. Each processes 4-lane vectors with a scalar tail; all loads
// and stores are unaligned (block buffers carry no alignment promise).
// ---------------------------------------------------------------------

macro_rules! bin_kernel {
    ($name:ident, $vop:ident, $assign:tt) => {
        #[target_feature(enable = "avx2")]
        unsafe fn $name(acc: &mut [f64], rhs: &[f64]) {
            let n = acc.len();
            let n4 = n - (n % 4);
            let mut i = 0;
            while i < n4 {
                let a = _mm256_loadu_pd(acc.as_ptr().add(i));
                let b = _mm256_loadu_pd(rhs.as_ptr().add(i));
                _mm256_storeu_pd(acc.as_mut_ptr().add(i), $vop(a, b));
                i += 4;
            }
            while i < n {
                acc[i] $assign rhs[i];
                i += 1;
            }
        }
    };
}

bin_kernel!(bin_add, _mm256_add_pd, +=);
bin_kernel!(bin_sub, _mm256_sub_pd, -=);
bin_kernel!(bin_mul, _mm256_mul_pd, *=);
bin_kernel!(bin_div, _mm256_div_pd, /=);

#[target_feature(enable = "avx2")]
unsafe fn bin_scalar_add(out: &mut [f64], s: f64) {
    let n = out.len();
    let n4 = n - (n % 4);
    let sv = _mm256_set1_pd(s);
    let mut i = 0;
    while i < n4 {
        let a = _mm256_loadu_pd(out.as_ptr().add(i));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_add_pd(a, sv));
        i += 4;
    }
    while i < n {
        out[i] += s;
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn bin_scalar_sub(out: &mut [f64], s: f64) {
    let n = out.len();
    let n4 = n - (n % 4);
    let sv = _mm256_set1_pd(s);
    let mut i = 0;
    while i < n4 {
        let a = _mm256_loadu_pd(out.as_ptr().add(i));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_sub_pd(a, sv));
        i += 4;
    }
    while i < n {
        out[i] -= s;
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn bin_scalar_mul(out: &mut [f64], s: f64) {
    let n = out.len();
    let n4 = n - (n % 4);
    let sv = _mm256_set1_pd(s);
    let mut i = 0;
    while i < n4 {
        let a = _mm256_loadu_pd(out.as_ptr().add(i));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_mul_pd(a, sv));
        i += 4;
    }
    while i < n {
        out[i] *= s;
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn un_neg(out: &mut [f64]) {
    let n = out.len();
    let n4 = n - (n % 4);
    // Sign-bit flip, exactly what scalar `-x` does (NaN payloads kept).
    let sign = _mm256_set1_pd(-0.0);
    let mut i = 0;
    while i < n4 {
        let a = _mm256_loadu_pd(out.as_ptr().add(i));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_xor_pd(a, sign));
        i += 4;
    }
    while i < n {
        out[i] = -out[i];
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn un_abs(out: &mut [f64]) {
    let n = out.len();
    let n4 = n - (n % 4);
    // Sign-bit clear, exactly what scalar `f64::abs` does.
    let sign = _mm256_set1_pd(-0.0);
    let mut i = 0;
    while i < n4 {
        let a = _mm256_loadu_pd(out.as_ptr().add(i));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_andnot_pd(sign, a));
        i += 4;
    }
    while i < n {
        out[i] = out[i].abs();
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn un_sqrt(out: &mut [f64]) {
    let n = out.len();
    let n4 = n - (n % 4);
    let mut i = 0;
    while i < n4 {
        let a = _mm256_loadu_pd(out.as_ptr().add(i));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_sqrt_pd(a));
        i += 4;
    }
    while i < n {
        out[i] = out[i].sqrt();
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn un_recip(out: &mut [f64]) {
    let n = out.len();
    let n4 = n - (n % 4);
    // A correctly rounded IEEE divide — never the `vrcpps`-style
    // approximation, which would break the bit contract.
    let ones = _mm256_set1_pd(1.0);
    let mut i = 0;
    while i < n4 {
        let a = _mm256_loadu_pd(out.as_ptr().add(i));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_div_pd(ones, a));
        i += 4;
    }
    while i < n {
        out[i] = 1.0 / out[i];
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn mul_add(dst: &mut [f64], a: &[f64], b: &[f64]) {
    let n = dst.len();
    let n4 = n - (n % 4);
    let mut i = 0;
    while i < n4 {
        let d = _mm256_loadu_pd(dst.as_ptr().add(i));
        let x = _mm256_loadu_pd(a.as_ptr().add(i));
        let y = _mm256_loadu_pd(b.as_ptr().add(i));
        // mul then add: two roundings, matching the scalar kernel.
        _mm256_storeu_pd(dst.as_mut_ptr().add(i), _mm256_add_pd(d, _mm256_mul_pd(x, y)));
        i += 4;
    }
    while i < n {
        dst[i] += a[i] * b[i];
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn mul_sub(dst: &mut [f64], a: &[f64], b: &[f64]) {
    let n = dst.len();
    let n4 = n - (n % 4);
    let mut i = 0;
    while i < n4 {
        let d = _mm256_loadu_pd(dst.as_ptr().add(i));
        let x = _mm256_loadu_pd(a.as_ptr().add(i));
        let y = _mm256_loadu_pd(b.as_ptr().add(i));
        _mm256_storeu_pd(dst.as_mut_ptr().add(i), _mm256_sub_pd(d, _mm256_mul_pd(x, y)));
        i += 4;
    }
    while i < n {
        dst[i] -= a[i] * b[i];
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn mul_streams(out: &mut [f64], a: &[f64], b: &[f64]) {
    let n = out.len();
    let n4 = n - (n % 4);
    let mut i = 0;
    while i < n4 {
        let x = _mm256_loadu_pd(a.as_ptr().add(i));
        let y = _mm256_loadu_pd(b.as_ptr().add(i));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_mul_pd(x, y));
        i += 4;
    }
    while i < n {
        out[i] = a[i] * b[i];
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn scale_add_const(dst: &mut [f64], mul: f64, add: f64) {
    let n = dst.len();
    let n4 = n - (n % 4);
    let mv = _mm256_set1_pd(mul);
    let av = _mm256_set1_pd(add);
    let mut i = 0;
    while i < n4 {
        let d = _mm256_loadu_pd(dst.as_ptr().add(i));
        _mm256_storeu_pd(dst.as_mut_ptr().add(i), _mm256_add_pd(_mm256_mul_pd(d, mv), av));
        i += 4;
    }
    while i < n {
        dst[i] = dst[i] * mul + add;
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn axpy_update(f: f64, dst: &mut [f64], src: &[f64]) {
    let n = dst.len();
    let n4 = n - (n % 4);
    let fv = _mm256_set1_pd(f);
    let mut i = 0;
    while i < n4 {
        let d = _mm256_loadu_pd(dst.as_ptr().add(i));
        let s = _mm256_loadu_pd(src.as_ptr().add(i));
        _mm256_storeu_pd(dst.as_mut_ptr().add(i), _mm256_add_pd(d, _mm256_mul_pd(fv, s)));
        i += 4;
    }
    while i < n {
        dst[i] += f * src[i];
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn sum_slice(xs: &[f64]) -> f64 {
    let n = xs.len();
    let n4 = n - (n % 4);
    // One 4-lane accumulator vector == the scalar contract's 4-way
    // unroll: lane j accumulates elements j, j+4, j+8, …
    let mut acc = _mm256_setzero_pd();
    let mut i = 0;
    while i < n4 {
        acc = _mm256_add_pd(acc, _mm256_loadu_pd(xs.as_ptr().add(i)));
        i += 4;
    }
    let mut s = hsum_contract(acc);
    while i < n {
        s += xs[i];
        i += 1;
    }
    s
}

#[target_feature(enable = "avx2")]
unsafe fn gather_mul_sum(vals: &[f64], x: &[f64], ix: &[i64]) -> f64 {
    let n = vals.len();
    let n4 = n - (n % 4);
    let mut acc = _mm256_setzero_pd();
    let mut t = 0;
    while t < n4 {
        // Lane-wise loads rather than `vgatherqpd`: same result, and
        // scalar f64 gathers are not slower on current cores. Indexing
        // stays checked — the trait method is safe and the scalar
        // reference panics on a bad index, so this must too.
        let xv = _mm256_set_pd(
            x[ix[t + 3] as usize],
            x[ix[t + 2] as usize],
            x[ix[t + 1] as usize],
            x[ix[t] as usize],
        );
        let vv = _mm256_loadu_pd(vals.as_ptr().add(t));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(vv, xv));
        t += 4;
    }
    let mut s = hsum_contract(acc);
    while t < n {
        s += vals[t] * x[ix[t] as usize];
        t += 1;
    }
    s
}

/// Horizontal sum in the contract's lane order: `((l0 + l1) + l2) + l3`.
#[target_feature(enable = "avx2")]
unsafe fn hsum_contract(v: __m256d) -> f64 {
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), v);
    lanes[0] + lanes[1] + lanes[2] + lanes[3]
}
