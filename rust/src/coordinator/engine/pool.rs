//! A fork-join worker pool.
//!
//! ArBB's runtime (pthreads/TBB underneath, §4 of the paper) executes each
//! vector operation as a parallel loop over chunks with a barrier before
//! the next operation — exactly the `run_chunks` shape below. Workers park
//! between jobs; the calling thread participates in chunk execution (so
//! `num_workers = 1` degenerates to the serial engine plus bookkeeping,
//! which is the measurable "O3 overhead" the paper's small-input results
//! show).
//!
//! Safety: jobs borrow stack data (`&dyn Fn`). `run_chunks` erases the
//! lifetime to publish the job to workers, and blocks until every chunk
//! completed — the borrow outlives all uses. This is the classic scoped-
//! thread-pool pattern.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A chunk-level task: `f(chunk_index)`.
type JobFn = dyn Fn(usize) + Sync;

struct Job {
    /// Lifetime-erased pointer to the caller's closure.
    f: *const JobFn,
    n_chunks: usize,
}
// SAFETY: the closure is Sync; the raw pointer is only dereferenced while
// `run_chunks` blocks on completion.
unsafe impl Send for Job {}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    next_chunk: AtomicUsize,
    done_chunks: AtomicUsize,
}

struct State {
    /// Monotonic job counter; workers watch it change.
    epoch: u64,
    job: Option<Job>,
    shutdown: bool,
}

/// Fork-join thread pool with a fixed worker count.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// Total workers *including* the calling thread.
    pub size: usize,
}

impl ThreadPool {
    /// `size` counts the calling thread: `new(4)` spawns 3 helpers.
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { epoch: 0, job: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next_chunk: AtomicUsize::new(0),
            done_chunks: AtomicUsize::new(0),
        });
        let workers = (1..size)
            .map(|w| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("arbb-worker-{w}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers, size }
    }

    /// Execute `f(0..n_chunks)` across the pool; blocks until complete.
    /// (`'a`: the closure may borrow stack data — see module docs.)
    pub fn run_chunks<'a>(&self, n_chunks: usize, f: &(dyn Fn(usize) + Sync + 'a)) {
        if n_chunks == 0 {
            return;
        }
        if self.size == 1 || n_chunks == 1 {
            for i in 0..n_chunks {
                f(i);
            }
            return;
        }
        // SAFETY: see module docs — we block until all chunks are done.
        let erased: *const JobFn = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync + 'a), &'static JobFn>(f)
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none(), "run_chunks is not reentrant");
            self.shared.next_chunk.store(0, Ordering::SeqCst);
            self.shared.done_chunks.store(0, Ordering::SeqCst);
            st.job = Some(Job { f: erased, n_chunks });
            st.epoch += 1;
            self.shared.work_cv.notify_all();
        }
        // The caller participates.
        loop {
            let i = self.shared.next_chunk.fetch_add(1, Ordering::SeqCst);
            if i >= n_chunks {
                break;
            }
            f(i);
            self.shared.done_chunks.fetch_add(1, Ordering::SeqCst);
        }
        // Wait for stragglers.
        let mut st = self.shared.state.lock().unwrap();
        while self.shared.done_chunks.load(Ordering::SeqCst) < n_chunks {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
    }
}

fn worker_loop(sh: Arc<Shared>) {
    let mut seen_epoch = 0u64;
    loop {
        // Wait for a new job (or shutdown).
        let (f, n_chunks) = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen_epoch {
                    if let Some(job) = &st.job {
                        seen_epoch = st.epoch;
                        break (job.f, job.n_chunks);
                    }
                }
                st = sh.work_cv.wait(st).unwrap();
            }
        };
        // Pull chunks.
        loop {
            let i = sh.next_chunk.fetch_add(1, Ordering::SeqCst);
            if i >= n_chunks {
                break;
            }
            // SAFETY: run_chunks keeps the closure alive until done.
            unsafe { (*f)(i) };
            let done = sh.done_chunks.fetch_add(1, Ordering::SeqCst) + 1;
            if done >= n_chunks {
                let _g = sh.state.lock().unwrap();
                sh.done_cv.notify_all();
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_chunks_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        pool.run_chunks(100, &|i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "chunk {i}");
        }
    }

    #[test]
    fn disjoint_writes() {
        let pool = ThreadPool::new(3);
        let n = 10_000usize;
        let mut out = vec![0.0f64; n];
        let chunk = 1000;
        let ptr = SendPtr(out.as_mut_ptr());
        let body = move |i: usize| {
            let ptr = ptr; // capture the SendPtr wrapper, not the raw field
            // SAFETY: disjoint ranges per chunk.
            let s = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * chunk), chunk) };
            for (k, x) in s.iter_mut().enumerate() {
                *x = (i * chunk + k) as f64;
            }
        };
        pool.run_chunks(n / chunk, &body);
        for (i, x) in out.iter().enumerate() {
            assert_eq!(*x, i as f64);
        }
    }

    #[test]
    fn sequential_jobs_reuse_pool() {
        let pool = ThreadPool::new(2);
        let counter = AtomicU64::new(0);
        for _ in 0..50 {
            pool.run_chunks(8, &|_| {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(counter.load(Ordering::SeqCst), 400);
    }

    #[test]
    fn single_worker_inline() {
        let pool = ThreadPool::new(1);
        let counter = AtomicU64::new(0);
        pool.run_chunks(5, &|_| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 5);
    }

    /// Helper to smuggle a raw pointer into a Sync closure.
    #[derive(Clone, Copy)]
    struct SendPtr(*mut f64);
    unsafe impl Send for SendPtr {}
    unsafe impl Sync for SendPtr {}
}
