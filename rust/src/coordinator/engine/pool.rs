//! A fork-join worker pool.
//!
//! ArBB's runtime (pthreads/TBB underneath, §4 of the paper) executes each
//! vector operation as a parallel loop over chunks with a barrier before
//! the next operation — exactly the `run_chunks` shape below. Workers park
//! between jobs; the calling thread participates in chunk execution (so
//! `num_workers = 1` degenerates to the serial engine plus bookkeeping,
//! which is the measurable "O3 overhead" the paper's small-input results
//! show).
//!
//! Safety: jobs borrow stack data (`&dyn Fn`). `run_chunks` erases the
//! lifetime to publish the job to workers, and blocks until every chunk
//! completed — the borrow outlives all uses. This is the classic scoped-
//! thread-pool pattern.

use std::any::Any;
use std::collections::HashMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;

use crate::obs::faults;

/// A panic payload captured from a chunk body: `(chunk index, payload)`.
type ChunkPanic = (usize, Box<dyn Any + Send>);

/// Best-effort human-readable text from a panic payload.
pub fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Poison-tolerant lock: pool state is always consistent at release
/// (panics in chunk bodies are caught before they can unwind through a
/// held guard), so a poisoned mutex carries no torn invariants.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A chunk-level task: `f(chunk_index)`.
type JobFn = dyn Fn(usize) + Sync;

struct Job {
    /// Lifetime-erased pointer to the caller's closure.
    f: *const JobFn,
    n_chunks: usize,
}
// SAFETY: the closure is Sync; the raw pointer is only dereferenced while
// `run_chunks` blocks on completion.
unsafe impl Send for Job {}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Chunk claim word, epoch-tagged: `(epoch & 0xFFFF_FFFF) << 32 |
    /// next_index`. Tagging closes a straggler race: a worker whose
    /// final claim attempt lands *after* the next job has been
    /// published must see a different tag and back off, instead of
    /// claiming chunk 0 of the new job against the old (dead) closure.
    claim: AtomicU64,
    done_chunks: AtomicUsize,
    /// Set when any chunk body of the current job panicked; the
    /// submitting thread re-raises after the barrier so a panicking
    /// body cannot kill a (process-shared) worker thread or wedge the
    /// barrier.
    job_panicked: AtomicBool,
    /// Panic payloads captured from the current job's chunk bodies,
    /// `(chunk index, payload)`. Drained by the submitter after the
    /// barrier — either re-raised ([`ThreadPool::run_chunks`]) or
    /// returned as data ([`ThreadPool::run_chunks_collect`]).
    panics: Mutex<Vec<ChunkPanic>>,
    /// Worker threads lost to a panic outside a chunk body and replaced
    /// by their [`Sentinel`] — the pool self-heals instead of shrinking.
    respawned: AtomicU64,
    /// Join handles for live workers. Lives in `Shared` (not the pool
    /// struct) so a sentinel respawning a dead worker can register the
    /// replacement for joining at drop.
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Shared {
    /// Claim the next chunk of the job tagged `tag`, or `None` when the
    /// job is exhausted or superseded.
    fn claim_chunk(&self, tag: u64, n_chunks: usize) -> Option<usize> {
        loop {
            let cur = self.claim.load(Ordering::SeqCst);
            if cur >> 32 != tag {
                return None; // a different job owns the claim word
            }
            let idx = (cur & 0xFFFF_FFFF) as usize;
            if idx >= n_chunks {
                return None;
            }
            if self
                .claim
                .compare_exchange(cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                return Some(idx);
            }
        }
    }

    /// Run chunk `i` of the current job with panic containment: a
    /// panicking body (or a tripped `pool.chunk.panic` failpoint) marks
    /// the job failed and parks its payload for the submitter.
    fn run_contained(&self, f: &JobFn, i: usize) {
        let r = catch_unwind(AssertUnwindSafe(|| {
            faults::fire_panic("pool.chunk.panic");
            f(i);
        }));
        if let Err(payload) = r {
            self.job_panicked.store(true, Ordering::SeqCst);
            relock(&self.panics).push((i, payload));
        }
    }
}

struct State {
    /// Monotonic job counter; workers watch it change. (The claim tag
    /// is its low 32 bits — a straggler would need to sleep through
    /// 2^32 jobs to alias.)
    epoch: u64,
    job: Option<Job>,
    shutdown: bool,
}

/// Fork-join thread pool with a fixed worker count.
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// Total workers *including* the calling thread.
    pub size: usize,
}

/// Spawn one worker thread and register its handle in `sh.handles`.
/// The worker carries a [`Sentinel`] so a panic that escapes the chunk
/// containment (e.g. the `pool.worker.die` failpoint) respawns it.
fn spawn_worker(sh: &Arc<Shared>, id: usize) {
    let sh2 = sh.clone();
    let h = std::thread::Builder::new()
        .name(format!("arbb-worker-{id}"))
        .spawn(move || {
            let _guard = Sentinel { sh: sh2.clone(), id };
            worker_loop(sh2);
        })
        .expect("spawn worker");
    relock(&sh.handles).push(h);
}

/// Respawns a worker whose thread died panicking. Chunk-body panics
/// never get here (they are contained in [`Shared::run_contained`]);
/// this covers panics in the dispatch loop itself, which would
/// otherwise permanently shrink a process-shared pool.
struct Sentinel {
    sh: Arc<Shared>,
    id: usize,
}

impl Drop for Sentinel {
    fn drop(&mut self) {
        if !std::thread::panicking() {
            return; // orderly shutdown
        }
        if relock(&self.sh.state).shutdown {
            return;
        }
        self.sh.respawned.fetch_add(1, Ordering::SeqCst);
        spawn_worker(&self.sh, self.id);
    }
}

impl ThreadPool {
    /// `size` counts the calling thread: `new(4)` spawns 3 helpers.
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { epoch: 0, job: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            claim: AtomicU64::new(u64::MAX), // tag no job ever uses
            done_chunks: AtomicUsize::new(0),
            job_panicked: AtomicBool::new(false),
            panics: Mutex::new(Vec::new()),
            respawned: AtomicU64::new(0),
            handles: Mutex::new(Vec::new()),
        });
        for w in 1..size {
            spawn_worker(&shared, w);
        }
        ThreadPool { shared, size }
    }

    /// Workers lost to a non-chunk panic and replaced since creation.
    pub fn workers_respawned(&self) -> u64 {
        self.shared.respawned.load(Ordering::SeqCst)
    }

    /// Execute `f(0..n_chunks)` across the pool; blocks until complete.
    /// (`'a`: the closure may borrow stack data — see module docs.)
    ///
    /// A panic in a chunk body is contained (the worker survives, the
    /// barrier completes) and re-raised on the calling thread after the
    /// job *with its original payload* — with a process-shared pool, a
    /// bad gather index or user elemental must not kill a worker every
    /// engine depends on, but the caller still sees the real message.
    pub fn run_chunks<'a>(&self, n_chunks: usize, f: &(dyn Fn(usize) + Sync + 'a)) {
        if n_chunks == 0 {
            return;
        }
        if self.size == 1 || n_chunks == 1 {
            // Inline: no shared state at risk, panics propagate as-is.
            for i in 0..n_chunks {
                faults::fire_panic("pool.chunk.panic");
                f(i);
            }
            return;
        }
        let mut panics = self.sweep(n_chunks, f);
        if let Some((_, payload)) = panics.drain(..).next() {
            resume_unwind(payload);
        }
    }

    /// [`Self::run_chunks`], but panics are returned as data instead of
    /// re-raised: `(chunk index, message)` per failed chunk, sorted by
    /// chunk. The serving dispatcher uses this so one poisoned request
    /// in a batch sweep fails *that request* without unwinding through
    /// the dispatcher thread.
    pub fn run_chunks_collect<'a>(
        &self,
        n_chunks: usize,
        f: &(dyn Fn(usize) + Sync + 'a),
    ) -> Vec<(usize, String)> {
        if n_chunks == 0 {
            return Vec::new();
        }
        if self.size == 1 || n_chunks == 1 {
            let mut failed = Vec::new();
            for i in 0..n_chunks {
                let r = catch_unwind(AssertUnwindSafe(|| {
                    faults::fire_panic("pool.chunk.panic");
                    f(i);
                }));
                if let Err(p) = r {
                    failed.push((i, panic_message(&*p)));
                }
            }
            return failed;
        }
        let mut failed: Vec<(usize, String)> = self
            .sweep(n_chunks, f)
            .into_iter()
            .map(|(i, p)| (i, panic_message(&*p)))
            .collect();
        failed.sort_unstable_by_key(|&(i, _)| i);
        failed
    }

    /// Publish one fork-join job, participate, wait for the barrier,
    /// and drain any captured chunk panics.
    fn sweep<'a>(&self, n_chunks: usize, f: &(dyn Fn(usize) + Sync + 'a)) -> Vec<ChunkPanic> {
        // SAFETY: see module docs — we block until all chunks are done,
        // and chunk claims are epoch-tagged so no worker can call this
        // closure after the job's barrier has completed.
        let erased: *const JobFn = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync + 'a), &'static JobFn>(f)
        };
        let tag;
        {
            let mut st = relock(&self.shared.state);
            debug_assert!(st.job.is_none(), "run_chunks is not reentrant");
            st.epoch += 1;
            tag = st.epoch & 0xFFFF_FFFF;
            self.shared.done_chunks.store(0, Ordering::SeqCst);
            self.shared.job_panicked.store(false, Ordering::SeqCst);
            relock(&self.shared.panics).clear();
            self.shared.claim.store(tag << 32, Ordering::SeqCst);
            st.job = Some(Job { f: erased, n_chunks });
            self.shared.work_cv.notify_all();
        }
        // The caller participates.
        while let Some(i) = self.shared.claim_chunk(tag, n_chunks) {
            self.shared.run_contained(f, i);
            self.shared.done_chunks.fetch_add(1, Ordering::SeqCst);
        }
        // Wait for stragglers.
        let mut st = relock(&self.shared.state);
        while self.shared.done_chunks.load(Ordering::SeqCst) < n_chunks {
            st = self.shared.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        drop(st);
        self.shared.job_panicked.store(false, Ordering::SeqCst);
        std::mem::take(&mut *relock(&self.shared.panics))
    }
}

fn worker_loop(sh: Arc<Shared>) {
    let mut seen_epoch = 0u64;
    loop {
        // Wait for a new job (or shutdown).
        let (f, n_chunks, tag) = {
            let mut st = relock(&sh.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen_epoch {
                    if let Some(job) = &st.job {
                        seen_epoch = st.epoch;
                        break (job.f, job.n_chunks, st.epoch & 0xFFFF_FFFF);
                    }
                }
                st = sh.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        // Failpoint: kill this worker *before* it claims any chunk (so
        // the job still completes via its peers) — exercises the
        // sentinel respawn path without wedging the barrier.
        faults::fire_panic("pool.worker.die");
        // Pull chunks (epoch-tagged: a stale claim attempt after this
        // job's barrier completed sees a different tag and backs off).
        while let Some(i) = sh.claim_chunk(tag, n_chunks) {
            // SAFETY: run_chunks keeps the closure alive until every
            // claimed chunk completed; claims stop at the tag change.
            // A panicking body is contained so this shared worker
            // survives and the barrier still completes.
            sh.run_contained(unsafe { &*f }, i);
            let done = sh.done_chunks.fetch_add(1, Ordering::SeqCst) + 1;
            if done >= n_chunks {
                let _g = relock(&sh.state);
                sh.done_cv.notify_all();
            }
        }
    }
}

/// A persistent, process-shared worker pool.
///
/// Wraps a [`ThreadPool`] behind a submission lock so that *multiple*
/// engines (every O3 [`super::super::Context`] plus the serving
/// dispatcher in [`crate::serve`]) can share one set of long-lived
/// worker threads instead of each spinning up its own. `run_chunks` is
/// not reentrant on the underlying pool; the lock serialises whole
/// fork-join sweeps, which is exactly the barrier semantics ArBB's
/// runtime exhibits (one vector operation in flight at a time).
///
/// Workers park between jobs, so an idle shared pool costs nothing but
/// memory. Pools are interned per worker count by [`shared`] and live
/// for the rest of the process. Besides the engines, the blocked
/// [`crate::kernels::dgemm_pooled`] comparator fans its row-panel loop
/// out over the same interned pools.
pub struct SharedPool {
    inner: ThreadPool,
    submit: Mutex<()>,
    jobs: AtomicU64,
    chunks: AtomicU64,
}

impl SharedPool {
    pub fn new(size: usize) -> Self {
        SharedPool {
            inner: ThreadPool::new(size),
            submit: Mutex::new(()),
            jobs: AtomicU64::new(0),
            chunks: AtomicU64::new(0),
        }
    }

    /// Total workers including the calling thread.
    pub fn size(&self) -> usize {
        self.inner.size
    }

    /// Execute `f(0..n_chunks)` as one fork-join sweep; blocks until
    /// complete. Sweeps from concurrent submitters are serialised.
    pub fn run_chunks<'a>(&self, n_chunks: usize, f: &(dyn Fn(usize) + Sync + 'a)) {
        if n_chunks == 0 {
            return;
        }
        self.jobs.fetch_add(1, Ordering::Relaxed);
        self.chunks.fetch_add(n_chunks as u64, Ordering::Relaxed);
        // A job whose body panicked re-raises on the submitting thread
        // and may poison this lock mid-unwind; the pool state itself is
        // already consistent by then, so poisoning is ignorable.
        let _guard = self.submit.lock().unwrap_or_else(|e| e.into_inner());
        self.inner.run_chunks(n_chunks, f);
    }

    /// [`ThreadPool::run_chunks_collect`] behind the submission lock:
    /// one serialised sweep, chunk panics returned as data.
    pub fn run_chunks_collect<'a>(
        &self,
        n_chunks: usize,
        f: &(dyn Fn(usize) + Sync + 'a),
    ) -> Vec<(usize, String)> {
        if n_chunks == 0 {
            return Vec::new();
        }
        self.jobs.fetch_add(1, Ordering::Relaxed);
        self.chunks.fetch_add(n_chunks as u64, Ordering::Relaxed);
        let _guard = self.submit.lock().unwrap_or_else(|e| e.into_inner());
        self.inner.run_chunks_collect(n_chunks, f)
    }

    /// Workers lost to a non-chunk panic and replaced since creation.
    pub fn workers_respawned(&self) -> u64 {
        self.inner.workers_respawned()
    }

    /// Fork-join sweeps dispatched since creation.
    pub fn jobs_dispatched(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }

    /// Chunk tasks executed since creation.
    pub fn chunks_run(&self) -> u64 {
        self.chunks.load(Ordering::Relaxed)
    }
}

/// Registry of shared pools, interned by `(label, worker count)`.
///
/// Label 0 is the process-default slice every O3 context and
/// single-shard server attaches to; the sharded serve scheduler interns
/// one slice per shard (label = shard index + 1) so each shard's sweeps
/// run on a disjoint set of long-lived workers and a hot plan's arenas
/// stay first-touched by the same threads.
static POOLS: OnceLock<Mutex<HashMap<(usize, usize), Arc<SharedPool>>>> = OnceLock::new();

/// The process-wide shared pool for `size` workers. The first caller
/// spawns the threads; everyone after that reuses them — per-dispatch
/// pool spawn/join is gone entirely.
pub fn shared(size: usize) -> Arc<SharedPool> {
    shared_labeled(0, size)
}

/// The process-wide shared pool for `(label, size)`. Distinct labels of
/// the same size are distinct pools with their own threads; `shared`
/// is label 0.
pub fn shared_labeled(label: usize, size: usize) -> Arc<SharedPool> {
    let size = size.max(1);
    let pools = POOLS.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = pools.lock().unwrap();
    map.entry((label, size)).or_insert_with(|| Arc::new(SharedPool::new(size))).clone()
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = relock(&self.shared.state);
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        // Drain until empty: a sentinel may push a replacement handle
        // while we are joining (its respawn raced the shutdown flag).
        loop {
            let Some(h) = relock(&self.shared.handles).pop() else { break };
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_chunks_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        pool.run_chunks(100, &|i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "chunk {i}");
        }
    }

    #[test]
    fn disjoint_writes() {
        let pool = ThreadPool::new(3);
        let n = 10_000usize;
        let mut out = vec![0.0f64; n];
        let chunk = 1000;
        let ptr = SendPtr(out.as_mut_ptr());
        let body = move |i: usize| {
            let ptr = ptr; // capture the SendPtr wrapper, not the raw field
            // SAFETY: disjoint ranges per chunk.
            let s = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * chunk), chunk) };
            for (k, x) in s.iter_mut().enumerate() {
                *x = (i * chunk + k) as f64;
            }
        };
        pool.run_chunks(n / chunk, &body);
        for (i, x) in out.iter().enumerate() {
            assert_eq!(*x, i as f64);
        }
    }

    #[test]
    fn sequential_jobs_reuse_pool() {
        let pool = ThreadPool::new(2);
        let counter = AtomicU64::new(0);
        for _ in 0..50 {
            pool.run_chunks(8, &|_| {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(counter.load(Ordering::SeqCst), 400);
    }

    #[test]
    fn single_worker_inline() {
        let pool = ThreadPool::new(1);
        let counter = AtomicU64::new(0);
        pool.run_chunks(5, &|_| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn shared_pool_serialises_concurrent_sweeps() {
        let pool = Arc::new(SharedPool::new(3));
        let counter = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = pool.clone();
            let c = counter.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..25 {
                    p.run_chunks(8, &|_| {
                        c.fetch_add(1, Ordering::SeqCst);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 4 * 25 * 8);
        assert_eq!(pool.jobs_dispatched(), 100);
        assert_eq!(pool.chunks_run(), 800);
    }

    #[test]
    fn panicking_chunk_body_does_not_wedge_the_pool() {
        let pool = SharedPool::new(3);
        // The panic is contained on the worker, re-raised on the
        // submitting thread after the barrier — with the original
        // payload, not a generic wrapper…
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.run_chunks(8, &|i| {
                if i == 3 {
                    panic!("boom in chunk {i}");
                }
            });
        }));
        let payload = res.expect_err("panic must be re-raised to the submitter");
        assert_eq!(panic_message(&*payload), "boom in chunk 3");
        // …and the pool (workers, barrier, submit lock) stays usable.
        let c = AtomicU64::new(0);
        pool.run_chunks(8, &|_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(c.load(Ordering::SeqCst), 8);
        assert_eq!(pool.workers_respawned(), 0, "a chunk panic must not cost a worker");
    }

    #[test]
    fn collect_variant_returns_panics_as_data() {
        let pool = SharedPool::new(3);
        let failed = pool.run_chunks_collect(8, &|i| {
            if i == 2 || i == 5 {
                panic!("bad chunk {i}");
            }
        });
        assert_eq!(failed.len(), 2);
        assert_eq!(failed[0], (2, "bad chunk 2".to_string()));
        assert_eq!(failed[1], (5, "bad chunk 5".to_string()));
        // A clean sweep right after returns no failures.
        assert!(pool.run_chunks_collect(8, &|_| {}).is_empty());
    }

    #[test]
    fn collect_variant_inline_path() {
        let pool = ThreadPool::new(1);
        let failed = pool.run_chunks_collect(3, &|i| {
            if i == 1 {
                panic!("inline boom");
            }
        });
        assert_eq!(failed, vec![(1, "inline boom".to_string())]);
    }

    #[test]
    fn shared_registry_interns_by_size() {
        let a = shared(2);
        let b = shared(2);
        assert!(Arc::ptr_eq(&a, &b), "same size must intern to the same pool");
        let c = shared(3);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(shared(0).size(), 1, "size clamps to at least 1");
    }

    #[test]
    fn labeled_registry_interns_by_label_and_size() {
        let base = shared(2);
        assert!(Arc::ptr_eq(&base, &shared_labeled(0, 2)), "label 0 is the default registry");
        let s1 = shared_labeled(7, 2);
        assert!(!Arc::ptr_eq(&base, &s1), "labels are distinct pools");
        assert!(Arc::ptr_eq(&s1, &shared_labeled(7, 2)));
        assert_eq!(shared_labeled(7, 0).size(), 1);
    }

    /// Helper to smuggle a raw pointer into a Sync closure.
    #[derive(Clone, Copy)]
    struct SendPtr(*mut f64);
    unsafe impl Send for SendPtr {}
    unsafe impl Sync for SendPtr {}
}
