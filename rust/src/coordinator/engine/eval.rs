//! Block-wise evaluator for fused expression trees.
//!
//! A lowered [`FExec`] tree is evaluated over a range of flat output
//! indices in cache-resident blocks: each operator processes one block
//! (`BLOCK` elements) at a time, so fused chains make a single pass over
//! main memory regardless of chain length — the optimisation ArBB's JIT
//! performs when it compiles a captured closure.

use std::sync::Arc;

use crate::coordinator::ops::{BinOp, UnOp};
use crate::coordinator::plan::FTree;
use crate::coordinator::shape::View;

/// Elements per evaluation block (16 KiB of f64 — comfortably L1-resident
/// together with a few scratch blocks).
pub const BLOCK: usize = 2048;

/// Execution-side fused tree: leaves are resolved to concrete buffers.
/// `Send + Sync` so parallel workers can share it.
#[derive(Debug, Clone)]
pub enum FExec {
    Leaf { data: Arc<Vec<f64>>, view: View },
    Const(f64),
    Iota,
    /// In-place accumulation marker: the output block already holds the
    /// base values; evaluating `Acc` is a no-op. Only valid as the
    /// left-most leaf (validated at lowering).
    Acc,
    Bin(BinOp, Box<FExec>, Box<FExec>),
    Un(UnOp, Box<FExec>),
}

impl FExec {
    /// Validate the `Acc` placement invariant: `Acc` may only appear on
    /// the left spine (so left-first evaluation never overwrites the base
    /// values before they are consumed).
    pub fn acc_placement_ok(&self) -> bool {
        fn scan(t: &FExec, leftmost: bool) -> bool {
            match t {
                FExec::Acc => leftmost,
                FExec::Bin(_, l, r) => scan(l, leftmost) && scan(r, false),
                FExec::Un(_, a) => scan(a, leftmost),
                _ => true,
            }
        }
        scan(self, true)
    }
}

/// Resolve an [`FTree`] into an executable [`FExec`], reading leaf
/// storages (all dependencies have been materialised by earlier steps).
///
/// A malformed plan — a leaf whose producing step is missing, or an
/// `Acc` marker off the left spine — is an [`crate::Error::Invalid`],
/// not a panic: a serving worker must survive a bad plan.
pub fn lower(tree: &FTree) -> crate::Result<FExec> {
    let fx = lower_inner(tree)?;
    if !fx.acc_placement_ok() {
        return Err(crate::Error::Invalid(
            "malformed plan: Acc leaf off the left spine".into(),
        ));
    }
    Ok(fx)
}

fn lower_inner(tree: &FTree) -> crate::Result<FExec> {
    Ok(match tree {
        FTree::Leaf { node, view } => {
            let data = node.data().ok_or_else(|| {
                crate::Error::Invalid(format!(
                    "malformed plan: leaf {} not materialised at lowering",
                    node.id
                ))
            })?;
            FExec::Leaf { data: data.as_f64().clone(), view: *view }
        }
        FTree::ScalarLeaf { node } => {
            let data = node.data().ok_or_else(|| {
                crate::Error::Invalid(format!(
                    "malformed plan: scalar leaf {} not materialised",
                    node.id
                ))
            })?;
            FExec::Const(data.as_f64()[0])
        }
        FTree::Const(c) => FExec::Const(*c),
        FTree::Iota => FExec::Iota,
        FTree::Acc => FExec::Acc,
        FTree::Bin(op, a, b) => {
            FExec::Bin(*op, Box::new(lower_inner(a)?), Box::new(lower_inner(b)?))
        }
        FTree::Un(op, a) => FExec::Un(*op, Box::new(lower_inner(a)?)),
    })
}

/// Scratch block pool: one per worker; blocks are recycled across
/// operators and evaluation calls.
#[derive(Default)]
pub struct Scratch {
    free: Vec<Vec<f64>>,
}

impl Scratch {
    pub fn take(&mut self) -> Vec<f64> {
        self.free.pop().unwrap_or_else(|| vec![0.0; BLOCK])
    }

    pub fn put(&mut self, b: Vec<f64>) {
        if self.free.len() < 64 {
            self.free.push(b);
        }
    }
}

thread_local! {
    static TLS_SCRATCH: std::cell::RefCell<Scratch> =
        std::cell::RefCell::new(Scratch::default());
}

/// Run `f` with this thread's persistent scratch pool (blocks survive
/// across steps and chunks — allocating per chunk showed up in profiles;
/// EXPERIMENTS.md §Perf iteration 2).
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    TLS_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Evaluate `fx` for flat output indices `[start, start+out.len())`.
///
/// The caller supplies arbitrary ranges (chunks); evaluation proceeds in
/// `BLOCK`-sized sub-blocks internally.
pub fn eval_range(fx: &FExec, start: usize, out: &mut [f64], scratch: &mut Scratch) {
    let mut off = 0;
    while off < out.len() {
        let len = BLOCK.min(out.len() - off);
        eval_block(fx, start + off, &mut out[off..off + len], scratch);
        off += len;
    }
}

/// Evaluate one block (`out.len() <= BLOCK`).
fn eval_block(fx: &FExec, start: usize, out: &mut [f64], scratch: &mut Scratch) {
    match fx {
        FExec::Const(c) => out.fill(*c),
        FExec::Iota => {
            for (k, o) in out.iter_mut().enumerate() {
                *o = (start + k) as f64;
            }
        }
        FExec::Acc => {
            // The output block already holds the accumulation base.
        }
        FExec::Leaf { data, view } => fill_view(data, view, start, out),
        FExec::Un(op, a) => {
            eval_block(a, start, out, scratch);
            // apply in place
            match op {
                UnOp::Neg => out.iter_mut().for_each(|x| *x = -*x),
                UnOp::Abs => out.iter_mut().for_each(|x| *x = x.abs()),
                UnOp::Sqrt => out.iter_mut().for_each(|x| *x = x.sqrt()),
                UnOp::Exp => out.iter_mut().for_each(|x| *x = x.exp()),
                UnOp::Ln => out.iter_mut().for_each(|x| *x = x.ln()),
                UnOp::Recip => out.iter_mut().for_each(|x| *x = 1.0 / *x),
            }
        }
        FExec::Bin(op, l, r) => {
            // Left into `out`, right into scratch, combine in place.
            eval_block(l, start, out, scratch);
            match &**r {
                FExec::Const(c) => op.apply_slice_scalar_inplace(out, *c),
                // Rank-1-update pattern (the arbb_mxm2a/2b hot loop):
                // out ±= colbcast(a) * rowleaf(b) — one fused pass, no
                // temporaries (EXPERIMENTS.md §Perf iteration 3).
                FExec::Bin(BinOp::Mul, p, q)
                    if matches!(op, BinOp::Add | BinOp::Sub)
                        && axpy_operands(p, q).is_some() =>
                {
                    let (da, va, db, vb) = axpy_operands(p, q).unwrap();
                    axpy_pattern(*op, da, va, db, vb, start, out);
                }
                _ => {
                    let mut tmp = scratch.take();
                    let t = &mut tmp[..out.len()];
                    eval_block(r, start, t, scratch);
                    op.apply_slices_inplace(out, t);
                    scratch.put(tmp);
                }
            }
        }
    }
}

/// Match the `colbcast(a) * rowleaf(b)` operand pair of a rank-1 update:
/// `p` broadcasts along columns (`col_stride == 0`, no modulo), `q` is a
/// unit-stride row view (possibly cyclic — `repeat_row` composes to a
/// modulo view). Returns the leaves in (bcast, row) order, commuting if
/// needed.
#[allow(clippy::type_complexity)]
fn axpy_operands<'a>(
    p: &'a FExec,
    q: &'a FExec,
) -> Option<(&'a [f64], &'a View, &'a [f64], &'a View)> {
    let classify = |t: &'a FExec| match t {
        FExec::Leaf { data, view } => Some((data.as_slice(), view)),
        _ => None,
    };
    let (pa, pv) = classify(p)?;
    let (qa, qv) = classify(q)?;
    let is_bcast = |v: &View| v.col_stride == 0 && v.modulo.is_none();
    let is_row = |v: &View| v.col_stride == 1;
    if is_bcast(pv) && is_row(qv) {
        Some((pa, pv, qa, qv))
    } else if is_bcast(qv) && is_row(pv) {
        Some((qa, qv, pa, pv))
    } else {
        None
    }
}

/// `out[seg] op= a_r * b[seg]` per output-row segment.
fn axpy_pattern(
    op: BinOp,
    da: &[f64],
    va: &View,
    db: &[f64],
    vb: &View,
    start: usize,
    out: &mut [f64],
) {
    let oc = va.out_cols.max(1);
    let len = out.len();
    let mut pos = 0usize;
    let mut r = start / oc;
    let mut c = start % oc;
    while pos < len {
        let seg = (oc - c).min(len - pos);
        let f = da[va.base + r * va.row_stride];
        let f = if op == BinOp::Sub { -f } else { f };
        // source segment through vb (cs == 1), splitting at cyclic wraps
        let mut done = 0usize;
        while done < seg {
            let lin = r * vb.row_stride + (c + done);
            let (off, room) = match vb.modulo {
                Some(m) => (lin % m, m - lin % m),
                None => (lin, usize::MAX),
            };
            let take = room.min(seg - done);
            let src = &db[vb.base + off..vb.base + off + take];
            let dst = &mut out[pos + done..pos + done + take];
            for i in 0..take {
                dst[i] += f * src[i];
            }
            done += take;
        }
        pos += seg;
        r += 1;
        c = 0;
    }
}

/// Gather a block through an affine view.
///
/// Decomposed into *row segments* of the output space so each segment is
/// one of four specialised inner loops (memcpy, broadcast fill, strided
/// gather, cyclic copy) — the per-element `(r, c)` bookkeeping of the
/// naive formulation was the single hottest path of the whole engine
/// (EXPERIMENTS.md §Perf, iteration 1).
fn fill_view(data: &[f64], view: &View, start: usize, out: &mut [f64]) {
    let len = out.len();
    // Fully contiguous: one memcpy.
    if view.is_contiguous() {
        let s = view.base + start;
        out.copy_from_slice(&data[s..s + len]);
        return;
    }
    let oc = view.out_cols.max(1);
    let mut pos = 0usize;
    let mut r = start / oc;
    let mut c = start % oc;
    while pos < len {
        let seg = (oc - c).min(len - pos);
        fill_segment(data, view, r, c, &mut out[pos..pos + seg]);
        pos += seg;
        r += 1;
        c = 0;
    }
}

/// Fill one output-row segment (constant `r`, columns `c0..c0+seg`).
#[inline]
fn fill_segment(data: &[f64], view: &View, r: usize, c0: usize, out: &mut [f64]) {
    let lin0 = r * view.row_stride + c0 * view.col_stride;
    match view.modulo {
        None => {
            let s0 = view.base + lin0;
            if view.col_stride == 0 {
                // row broadcast (repeat_col leaves): constant segment
                out.fill(data[s0]);
            } else if view.col_stride == 1 {
                // unit stride within the row (repeat_row / row views)
                out.copy_from_slice(&data[s0..s0 + out.len()]);
            } else {
                // strided gather (column views, strided sections)
                let cs = view.col_stride;
                let mut s = s0;
                for o in out.iter_mut() {
                    *o = data[s];
                    s += cs;
                }
            }
        }
        Some(m) => {
            // cyclic view (repeat): wrap by subtraction — col_stride never
            // exceeds the period by construction (compose scales both).
            let cs = view.col_stride;
            let mut lin = lin0 % m;
            for o in out.iter_mut() {
                *o = data[view.base + lin];
                lin += cs;
                if lin >= m {
                    lin %= m;
                }
            }
        }
    }
}

impl BinOp {
    /// `out[i] = op(out[i], s)` — scalar right operand, in place.
    #[inline]
    pub fn apply_slice_scalar_inplace(self, out: &mut [f64], s: f64) {
        match self {
            BinOp::Add => out.iter_mut().for_each(|x| *x += s),
            BinOp::Sub => out.iter_mut().for_each(|x| *x -= s),
            BinOp::Mul => out.iter_mut().for_each(|x| *x *= s),
            BinOp::Div => {
                let inv = 1.0 / s;
                out.iter_mut().for_each(|x| *x *= inv)
            }
            BinOp::Min => out.iter_mut().for_each(|x| *x = x.min(s)),
            BinOp::Max => out.iter_mut().for_each(|x| *x = x.max(s)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaf(data: Vec<f64>, view: View) -> FExec {
        FExec::Leaf { data: Arc::new(data), view }
    }

    #[test]
    fn eval_contiguous_add() {
        let a = leaf(vec![1.0, 2.0, 3.0, 4.0], View::identity(4));
        let b = leaf(vec![10.0, 20.0, 30.0, 40.0], View::identity(4));
        let fx = FExec::Bin(BinOp::Add, Box::new(a), Box::new(b));
        let mut out = vec![0.0; 4];
        eval_range(&fx, 0, &mut out, &mut Scratch::default());
        assert_eq!(out, vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn eval_scalar_rhs() {
        let a = leaf(vec![1.0, 2.0], View::identity(2));
        let fx = FExec::Bin(BinOp::Mul, Box::new(a), Box::new(FExec::Const(3.0)));
        let mut out = vec![0.0; 2];
        eval_range(&fx, 0, &mut out, &mut Scratch::default());
        assert_eq!(out, vec![3.0, 6.0]);
    }

    #[test]
    fn eval_strided_view() {
        // even elements of an 8-vector
        let v = View { base: 0, row_stride: 0, col_stride: 2, out_cols: 4, modulo: None };
        let fx = leaf((0..8).map(|x| x as f64).collect(), v);
        let mut out = vec![0.0; 4];
        eval_range(&fx, 0, &mut out, &mut Scratch::default());
        assert_eq!(out, vec![0.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn eval_modulo_view() {
        let v = View { base: 0, row_stride: 4, col_stride: 1, out_cols: 4, modulo: Some(2) };
        let fx = leaf(vec![7.0, 9.0], v);
        let mut out = vec![0.0; 8];
        eval_range(&fx, 0, &mut out, &mut Scratch::default());
        assert_eq!(out, vec![7.0, 9.0, 7.0, 9.0, 7.0, 9.0, 7.0, 9.0]);
    }

    #[test]
    fn eval_range_with_offset() {
        // Evaluating a sub-range must agree with evaluating the whole.
        let n = 100;
        let data: Vec<f64> = (0..n).map(|x| (x * x) as f64).collect();
        let fx = FExec::Un(
            UnOp::Sqrt,
            Box::new(leaf(data.clone(), View::identity(10))),
        );
        let mut full = vec![0.0; n];
        eval_range(&fx, 0, &mut full, &mut Scratch::default());
        let mut part = vec![0.0; 30];
        eval_range(&fx, 25, &mut part, &mut Scratch::default());
        assert_eq!(&full[25..55], part.as_slice());
    }

    #[test]
    fn eval_iota() {
        let fx = FExec::Iota;
        let mut out = vec![0.0; 5];
        eval_range(&fx, 10, &mut out, &mut Scratch::default());
        assert_eq!(out, vec![10.0, 11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn acc_placement() {
        let ok = FExec::Bin(
            BinOp::Add,
            Box::new(FExec::Acc),
            Box::new(FExec::Const(1.0)),
        );
        assert!(ok.acc_placement_ok());
        let bad = FExec::Bin(
            BinOp::Add,
            Box::new(FExec::Const(1.0)),
            Box::new(FExec::Acc),
        );
        assert!(!bad.acc_placement_ok());
    }

    #[test]
    fn eval_accumulate_inplace() {
        // out starts as base; fx = Acc + leaf
        let addend = leaf(vec![1.0, 2.0, 3.0], View::identity(3));
        let fx = FExec::Bin(BinOp::Add, Box::new(FExec::Acc), Box::new(addend));
        let mut out = vec![10.0, 20.0, 30.0];
        eval_range(&fx, 0, &mut out, &mut Scratch::default());
        assert_eq!(out, vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn lower_unmaterialised_leaf_is_error_not_panic() {
        use crate::coordinator::node::{Node, Op};
        use crate::coordinator::shape::{DType, Shape};
        // A pending node with no storage: lowering a plan that references
        // it must produce Error::Invalid (a serving worker must survive).
        let pending = Node::new(Op::Iota(4), Shape::D1(4), DType::F64);
        let tree = FTree::Leaf { node: pending, view: View::identity(4) };
        match lower(&tree) {
            Err(crate::Error::Invalid(msg)) => {
                assert!(msg.contains("not materialised"), "{msg}")
            }
            other => panic!("expected Error::Invalid, got {other:?}"),
        }
    }

    #[test]
    fn lower_rejects_acc_off_left_spine() {
        let bad = FTree::Bin(
            BinOp::Add,
            Box::new(FTree::Const(1.0)),
            Box::new(FTree::Acc),
        );
        assert!(lower(&bad).is_err());
    }

    #[test]
    fn blocks_cross_boundaries() {
        let n = BLOCK * 3 + 17;
        let data: Vec<f64> = (0..n).map(|x| x as f64).collect();
        let fx = FExec::Bin(
            BinOp::Add,
            Box::new(leaf(data.clone(), View::identity(n))),
            Box::new(FExec::Const(0.5)),
        );
        let mut out = vec![0.0; n];
        eval_range(&fx, 0, &mut out, &mut Scratch::default());
        for i in [0, 1, BLOCK - 1, BLOCK, 2 * BLOCK + 5, n - 1] {
            assert_eq!(out[i], i as f64 + 0.5);
        }
    }
}
