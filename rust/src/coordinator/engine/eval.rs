//! Block-wise evaluation of fused expression trees: a reference tree
//! interpreter plus the production tape compiler + register VM.
//!
//! A lowered [`FExec`] tree is evaluated over a range of flat output
//! indices in cache-resident blocks: each operator processes one block
//! (`BLOCK` elements) at a time, so fused chains make a single pass over
//! main memory regardless of chain length — the optimisation ArBB's JIT
//! performs when it compiles a captured closure.
//!
//! Two executors share that blocking discipline:
//!
//!  * [`eval_range`] — the original recursive **tree interpreter**. It
//!    re-walks the boxed tree for every block; retained as the reference
//!    semantics (the property tests compare the tape VM against it
//!    bit-for-bit) and as the ablation baseline.
//!  * [`Tape`] — the **tape compiler + register VM**. The tree is
//!    lowered post-order, once, into a flat `Vec<Instr>` over virtual
//!    block registers; a free-list register allocator reuses registers
//!    as their live ranges end, so the peak register count is the depth
//!    of the deepest right spine, not the operator count. Leaf loads
//!    are monomorphised per view shape ([`Instr::LoadContiguous`] /
//!    `LoadBroadcast` / `LoadStrided` / `LoadModulo` / `LoadSplat`)
//!    replacing the generic dispatch of `fill_view`, and the hot
//!    operator shapes collapse into fused superinstructions
//!    ([`Instr::MulAdd`], [`Instr::Axpy`], [`Instr::ScaleAddConst`])
//!    that subsume the tree interpreter's hand-matched rank-1-update
//!    special case and remove whole block passes. See EXPERIMENTS.md
//!    §"Tape VM" for the design notes and microbenchmark results.
//!
//! The per-block compute kernels themselves live in
//! [`super::backend`]: a compiled tape carries the [`Backend`] it was
//! compiled against (scalar reference or runtime-detected SIMD) and
//! dispatches every operator, superinstruction and reduction fold
//! through it. The tree interpreter always runs the scalar backend —
//! it is the bit-exact comparator the property suites hold every
//! backend to.

use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::Instant;

use crate::coordinator::node::Data;
use crate::coordinator::ops::{BinOp, RedOp, UnOp};
use crate::coordinator::plan::FTree;
use crate::coordinator::shape::View;
use crate::obs::profile::{self, LocalBlock, OpClass};

use super::backend::{self, Backend};

/// Elements per evaluation block (16 KiB of f64).
///
/// Tuning rationale (EXPERIMENTS.md §"Tape VM"): the block must be small
/// enough that the output block plus the tape's live registers (typically
/// 1–3, worst case the right-spine depth of the fused tree) stay
/// L1/L2-resident — at 2048 elements four live blocks occupy 64 KiB —
/// yet large enough that per-block dispatch (one linear scan of the
/// instruction tape, or one tree walk for the reference interpreter)
/// amortises to noise against the ~2048-iteration inner loops. Halving
/// it doubles dispatch overhead with no locality gain; doubling it
/// spills deep chains' register files out of L1.
///
/// Defined in [`super::tuning`] with the rest of the sizing constants;
/// re-exported here because the tape VM is its primary consumer.
pub use super::tuning::BLOCK;

/// Execution-side fused tree: leaves are resolved to concrete buffers.
/// `Send + Sync` so parallel workers can share it.
#[derive(Debug, Clone)]
pub enum FExec {
    Leaf { data: Arc<Vec<f64>>, view: View },
    /// Fused gather leaf: element `k` reads `data[idx[base + k]]` (the
    /// spmv index traffic, absorbed into the fused pass).
    Gather { data: Arc<Vec<f64>>, idx: Arc<Vec<i64>>, base: usize },
    Const(f64),
    Iota,
    /// In-place accumulation marker: the output block already holds the
    /// base values; evaluating `Acc` is a no-op. Only valid as the
    /// left-most leaf (validated at lowering).
    Acc,
    Bin(BinOp, Box<FExec>, Box<FExec>),
    Un(UnOp, Box<FExec>),
}

impl FExec {
    /// Validate the `Acc` placement invariant: `Acc` may only appear on
    /// the left spine (so left-first evaluation never overwrites the base
    /// values before they are consumed).
    pub fn acc_placement_ok(&self) -> bool {
        fn scan(t: &FExec, leftmost: bool) -> bool {
            match t {
                FExec::Acc => leftmost,
                FExec::Bin(_, l, r) => scan(l, leftmost) && scan(r, false),
                FExec::Un(_, a) => scan(a, leftmost),
                _ => true,
            }
        }
        scan(self, true)
    }
}

/// Resolve an [`FTree`] into an executable [`FExec`], reading leaf
/// storages (all dependencies have been materialised by earlier steps).
///
/// A malformed plan — a leaf whose producing step is missing, or an
/// `Acc` marker off the left spine — is an [`crate::Error::Invalid`],
/// not a panic: a serving worker must survive a bad plan.
pub fn lower(tree: &FTree) -> crate::Result<FExec> {
    let fx = lower_inner(tree)?;
    if !fx.acc_placement_ok() {
        return Err(crate::Error::Invalid(
            "malformed plan: Acc leaf off the left spine".into(),
        ));
    }
    Ok(fx)
}

fn lower_inner(tree: &FTree) -> crate::Result<FExec> {
    Ok(match tree {
        FTree::Leaf { node, view } => {
            let data = node.data().ok_or_else(|| {
                crate::Error::Invalid(format!(
                    "malformed plan: leaf {} not materialised at lowering",
                    node.id
                ))
            })?;
            let Data::F64(buf) = data else {
                return Err(crate::Error::Invalid(format!(
                    "malformed plan: f64 leaf {} holds an i64 container",
                    node.id
                )));
            };
            FExec::Leaf { data: buf, view: *view }
        }
        FTree::ScalarLeaf { node } => {
            let data = node.data().ok_or_else(|| {
                crate::Error::Invalid(format!(
                    "malformed plan: scalar leaf {} not materialised",
                    node.id
                ))
            })?;
            let Data::F64(buf) = data else {
                return Err(crate::Error::Invalid(format!(
                    "malformed plan: scalar leaf {} holds an i64 container",
                    node.id
                )));
            };
            let c = *buf.first().ok_or_else(|| {
                crate::Error::Invalid(format!(
                    "malformed plan: scalar leaf {} is empty",
                    node.id
                ))
            })?;
            FExec::Const(c)
        }
        FTree::Gather { src, idx, base } => {
            let data = src.data().ok_or_else(|| {
                crate::Error::Invalid(format!(
                    "malformed plan: gather source {} not materialised at lowering",
                    src.id
                ))
            })?;
            let ix = idx.data().ok_or_else(|| {
                crate::Error::Invalid(format!(
                    "malformed plan: gather index {} not materialised at lowering",
                    idx.id
                ))
            })?;
            let (Data::F64(buf), Data::I64(ixbuf)) =
                (data, ix)
            else {
                return Err(crate::Error::Invalid(format!(
                    "malformed plan: gather {}[{}] has mismatched container types \
                     (source must be f64, index must be i64)",
                    src.id, idx.id
                )));
            };
            FExec::Gather { data: buf, idx: ixbuf, base: *base }
        }
        FTree::Const(c) => FExec::Const(*c),
        FTree::Iota => FExec::Iota,
        FTree::Acc => FExec::Acc,
        FTree::Bin(op, a, b) => {
            FExec::Bin(*op, Box::new(lower_inner(a)?), Box::new(lower_inner(b)?))
        }
        FTree::Un(op, a) => FExec::Un(*op, Box::new(lower_inner(a)?)),
    })
}

/// Scratch block pool: one per worker; blocks are recycled across
/// operators and evaluation calls.
#[derive(Default)]
pub struct Scratch {
    free: Vec<Vec<f64>>,
    /// Cached tape register file (tapes never nest on one thread, so a
    /// single cached file suffices; it grows to the largest request and
    /// is reused allocation-free from then on).
    file: Option<Vec<f64>>,
}

impl Scratch {
    pub fn take(&mut self) -> Vec<f64> {
        self.free.pop().unwrap_or_else(|| vec![0.0; BLOCK])
    }

    pub fn put(&mut self, b: Vec<f64>) {
        if self.free.len() < 64 {
            self.free.push(b);
        }
    }

    /// Take the thread-cached tape register file, grown to at least
    /// `len` elements. Steady state performs no allocation.
    pub fn take_file(&mut self, len: usize) -> Vec<f64> {
        let mut f = self.file.take().unwrap_or_default();
        if f.len() < len {
            f.resize(len, 0.0);
        }
        f
    }

    /// Return a register file; the largest seen so far is kept.
    pub fn put_file(&mut self, f: Vec<f64>) {
        match &self.file {
            Some(cur) if cur.len() >= f.len() => {}
            _ => self.file = Some(f),
        }
    }
}

thread_local! {
    static TLS_SCRATCH: std::cell::RefCell<Scratch> =
        std::cell::RefCell::new(Scratch::default());
}

/// Run `f` with this thread's persistent scratch pool (blocks survive
/// across steps and chunks — allocating per chunk showed up in profiles;
/// EXPERIMENTS.md §Perf iteration 2).
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    TLS_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

// ---------------------------------------------------------------------
// Reference tree interpreter
// ---------------------------------------------------------------------

/// Evaluate `fx` for flat output indices `[start, start+out.len())`.
///
/// The caller supplies arbitrary ranges (chunks); evaluation proceeds in
/// `BLOCK`-sized sub-blocks internally.
pub fn eval_range(fx: &FExec, start: usize, out: &mut [f64], scratch: &mut Scratch) {
    let mut off = 0;
    while off < out.len() {
        let len = BLOCK.min(out.len() - off);
        eval_block(fx, start + off, &mut out[off..off + len], scratch);
        off += len;
    }
}

/// Evaluate one block (`out.len() <= BLOCK`).
fn eval_block(fx: &FExec, start: usize, out: &mut [f64], scratch: &mut Scratch) {
    match fx {
        FExec::Const(c) => out.fill(*c),
        FExec::Iota => {
            for (k, o) in out.iter_mut().enumerate() {
                *o = (start + k) as f64;
            }
        }
        FExec::Acc => {
            // The output block already holds the accumulation base.
        }
        FExec::Leaf { data, view } => backend::fill_view(data, view, start, out),
        FExec::Gather { data, idx, base } => {
            for (k, o) in out.iter_mut().enumerate() {
                *o = data[idx[base + start + k] as usize];
            }
        }
        FExec::Un(op, a) => {
            eval_block(a, start, out, scratch);
            op.apply_slice_inplace(out);
        }
        FExec::Bin(op, l, r) => {
            // Left into `out`, right into scratch, combine in place.
            eval_block(l, start, out, scratch);
            let fused = match &**r {
                FExec::Const(c) => {
                    op.apply_slice_scalar_inplace(out, *c);
                    true
                }
                // Rank-1-update pattern (the arbb_mxm2a/2b hot loop):
                // out ±= colbcast(a) * rowleaf(b) — one fused pass, no
                // temporaries (EXPERIMENTS.md §Perf iteration 3).
                FExec::Bin(BinOp::Mul, p, q) if matches!(op, BinOp::Add | BinOp::Sub) => {
                    if let Some((da, va, db, vb)) = axpy_operands(p, q) {
                        backend::axpy_pattern(backend::scalar(), *op, da, va, db, vb, start, out);
                        true
                    } else {
                        false
                    }
                }
                _ => false,
            };
            if !fused {
                let mut tmp = scratch.take();
                let t = &mut tmp[..out.len()];
                eval_block(r, start, t, scratch);
                op.apply_slices_inplace(out, t);
                scratch.put(tmp);
            }
        }
    }
}

/// Match the `colbcast(a) * rowleaf(b)` operand pair of a rank-1 update:
/// `p` broadcasts along columns (`col_stride == 0`, no modulo), `q` is a
/// unit-stride row view (possibly cyclic — `repeat_row` composes to a
/// modulo view). Returns the leaves in (bcast, row) order, commuting if
/// needed.
#[allow(clippy::type_complexity)]
fn axpy_operands<'a>(
    p: &'a FExec,
    q: &'a FExec,
) -> Option<(&'a [f64], &'a View, &'a [f64], &'a View)> {
    let classify = |t: &'a FExec| match t {
        FExec::Leaf { data, view } => Some((data.as_slice(), view)),
        _ => None,
    };
    let (pa, pv) = classify(p)?;
    let (qa, qv) = classify(q)?;
    let is_bcast = |v: &View| v.col_stride == 0 && v.modulo.is_none();
    let is_row = |v: &View| v.col_stride == 1;
    if is_bcast(pv) && is_row(qv) {
        Some((pa, pv, qa, qv))
    } else if is_bcast(qv) && is_row(pv) {
        Some((qa, qv, pa, pv))
    } else {
        None
    }
}

// The monomorphised leaf loaders (`load_contiguous`/`load_broadcast`/
// `load_strided`/`load_modulo`/`fill_view`), the rank-1 `axpy_pattern`
// walk and the scalar-operand kernels now live in [`super::backend`] —
// one implementation shared by the tree interpreter, the tape VM, the
// segmented executor and the serving replay.

// ---------------------------------------------------------------------
// Tape compiler + register VM
// ---------------------------------------------------------------------

/// Virtual block-register index. Register 0 is the output block; higher
/// registers are `BLOCK`-sized lanes of a per-thread scratch file.
pub type Reg = u16;

/// Hard cap on virtual registers per tape. The free-list allocator keeps
/// the peak at the right-spine depth of the fused tree, which the
/// planner bounds at [`crate::coordinator::plan::MAX_FUSE_OPS`]; the cap
/// only guards hand-built trees.
const MAX_REGS: usize = 4096;

/// A raw leaf binding (`ptr`, `len`), the allocation-free way to hand a
/// resolved buffer set to [`TapeProgram::run_range_raw`].
pub type LeafBind = (*const f64, usize);

/// A raw i64 leaf binding: the index tables gather loaders read through.
pub type ILeafBind = (*const i64, usize);

/// Leaf-indexed fused tree: the tape compiler's input. Both the engine's
/// [`FExec`] (Arc-resolved leaves) and the serving layer's graph-free
/// trees lower into this, keeping buffer resolution out of the compiler.
#[derive(Debug, Clone)]
pub enum KTree {
    Leaf { leaf: u16, view: View },
    /// Broadcast of the single element `leaves[leaf][idx]`, bound at
    /// run time (serving scalar parameters resolve here).
    Splat { leaf: u16, idx: usize },
    /// Gather leaf: element `k` reads `leaves[src][ileaves[idx][base + k]]`
    /// — the i64 index table is a separate binding namespace so index
    /// containers rebind per run exactly like data leaves.
    Gather { src: u16, idx: u16, base: usize },
    Const(f64),
    Iota,
    Acc,
    Bin(BinOp, Box<KTree>, Box<KTree>),
    Un(UnOp, Box<KTree>),
}

/// One tape instruction. All instructions operate on the current block:
/// loads materialise a leaf segment into a register, operator
/// instructions mutate their `dst` register in place, and the fused
/// superinstructions (`MulAdd`/`MulSub`/`ScaleAddConst`/`Axpy`) combine
/// what the tree interpreter needs several block passes for into one.
#[derive(Debug, Clone, Copy)]
pub enum Instr {
    /// `dst <- leaf[base + i]` (contiguous view: one memcpy).
    LoadContiguous { dst: Reg, leaf: u16, base: usize },
    /// `dst <- broadcast(leaf[idx])`.
    LoadSplat { dst: Reg, leaf: u16, idx: usize },
    /// `dst <- leaf` through a column-broadcast view.
    LoadBroadcast { dst: Reg, leaf: u16, view: View },
    /// `dst <- leaf` through a strided (modulo-free) view.
    LoadStrided { dst: Reg, leaf: u16, view: View },
    /// `dst <- leaf` through a cyclic view.
    LoadModulo { dst: Reg, leaf: u16, view: View },
    /// `dst[k] <- leaf[ileaf_idx[base + start + k]]` — the monomorphised
    /// gather loader (spmv index traffic inside the fused pass).
    LoadGather { dst: Reg, leaf: u16, idx: u16, base: usize },
    /// `dst <- broadcast(val)`.
    LoadConst { dst: Reg, val: f64 },
    /// `dst[k] <- (start + k) as f64`.
    LoadIota { dst: Reg },
    /// `dst <- op(dst, rhs)`.
    Bin { op: BinOp, dst: Reg, rhs: Reg },
    /// `dst <- op(dst, val)`.
    BinConst { op: BinOp, dst: Reg, val: f64 },
    /// `dst <- op(dst, leaf[idx])` — runtime-bound scalar operand.
    BinSplat { op: BinOp, dst: Reg, leaf: u16, idx: usize },
    /// `dst <- op(dst)`.
    Un { op: UnOp, dst: Reg },
    /// `dst[i] += a[i] * b[i]` — one pass instead of mul-into-scratch
    /// plus add-from-scratch.
    MulAdd { dst: Reg, a: Reg, b: Reg },
    /// `dst[i] -= a[i] * b[i]`.
    MulSub { dst: Reg, a: Reg, b: Reg },
    /// `dst[i] = dst[i] * mul + add` — peephole of adjacent scalar
    /// multiply and add/subtract.
    ScaleAddConst { dst: Reg, mul: f64, add: f64 },
    /// Rank-1 update: `dst[seg] ±= a_row * b[seg]` with `a` a
    /// column-broadcast leaf and `b` a unit-stride row leaf — subsumes
    /// the tree interpreter's hand-matched special case.
    Axpy { dst: Reg, sub: bool, a: u16, av: View, b: u16, bv: View },
}

/// A compiled, leaf-abstract tape: the instruction stream plus register
/// and leaf counts, bound to the [`Backend`] whose kernels execute it.
/// `Send + Sync`; bind leaves per run.
#[derive(Debug)]
pub struct TapeProgram {
    instrs: Vec<Instr>,
    /// Scratch registers beyond the output register (peak liveness after
    /// free-list reuse).
    n_scratch: usize,
    n_leaves: usize,
    /// i64 index-table bindings referenced by gather loaders.
    n_ileaves: usize,
    /// Kernel backend every block of this tape runs through (fixed at
    /// compile; all backends are bit-identical by contract).
    bk: &'static dyn Backend,
}

impl TapeProgram {
    /// Lower a leaf-indexed fused tree post-order into a flat tape,
    /// executing through the process-wide [`backend::active`] backend.
    pub fn compile(tree: &KTree) -> crate::Result<TapeProgram> {
        Self::compile_with(tree, backend::active())
    }

    /// As [`TapeProgram::compile`], against an explicit backend (the
    /// engine threads its context's selection; tests force scalar vs
    /// SIMD side by side).
    pub fn compile_with(tree: &KTree, bk: &'static dyn Backend) -> crate::Result<TapeProgram> {
        let mut b = TapeBuilder {
            instrs: Vec::new(),
            free: Vec::new(),
            next: 1,
            high: 1,
            n_leaves: 0,
            n_ileaves: 0,
        };
        b.lower(tree, 0)?;
        let instrs = peephole(b.instrs);
        Ok(TapeProgram {
            instrs,
            n_scratch: b.high - 1,
            n_leaves: b.n_leaves,
            n_ileaves: b.n_ileaves,
            bk,
        })
    }

    /// The kernel backend this tape was compiled against.
    pub fn backend(&self) -> &'static dyn Backend {
        self.bk
    }

    pub fn n_instrs(&self) -> usize {
        self.instrs.len()
    }

    /// Scratch registers beyond the output register (peak liveness).
    pub fn n_scratch_regs(&self) -> usize {
        self.n_scratch
    }

    pub fn n_leaves(&self) -> usize {
        self.n_leaves
    }

    pub fn n_ileaves(&self) -> usize {
        self.n_ileaves
    }

    pub fn instrs(&self) -> &[Instr] {
        &self.instrs
    }

    /// Per-opcode-class instruction counts — the static shape the
    /// calibrated cost model ([`super::cost`]) prices: estimated
    /// ns/elem of one tape pass = Σ count(class) · calibrated
    /// ns/elem(class).
    pub fn class_histogram(&self) -> [u32; profile::N_CLASSES] {
        let mut h = [0u32; profile::N_CLASSES];
        for ins in &self.instrs {
            h[class_of(ins) as usize] += 1;
        }
        h
    }

    /// Execute over output indices `[start, start + out.len())` with
    /// `leaves[i]` bound to the i-th leaf buffer and `ileaves[i]` to the
    /// i-th index table.
    pub fn run_range(
        &self,
        leaves: &[&[f64]],
        ileaves: &[&[i64]],
        start: usize,
        out: &mut [f64],
        scratch: &mut Scratch,
    ) {
        let raw: Vec<LeafBind> = leaves.iter().map(|s| (s.as_ptr(), s.len())).collect();
        let iraw: Vec<ILeafBind> = ileaves.iter().map(|s| (s.as_ptr(), s.len())).collect();
        // SAFETY: `raw`/`iraw` point into `leaves`/`ileaves`, which
        // outlive this call.
        unsafe { self.run_range_raw(&raw, &iraw, start, out, scratch) }
    }

    /// Allocation-free entry: leaves are pre-resolved raw bindings (the
    /// serving replay arena recycles the binding vectors across calls).
    ///
    /// # Safety
    ///
    /// Every `(ptr, len)` in `leaves`/`ileaves` must describe a live,
    /// initialised buffer for the duration of the call, none of which
    /// overlaps `out`.
    pub unsafe fn run_range_raw(
        &self,
        leaves: &[LeafBind],
        ileaves: &[ILeafBind],
        start: usize,
        out: &mut [f64],
        scratch: &mut Scratch,
    ) {
        debug_assert!(leaves.len() >= self.n_leaves, "tape run with too few leaf bindings");
        debug_assert!(
            ileaves.len() >= self.n_ileaves,
            "tape run with too few index-table bindings"
        );
        let mut file = scratch.take_file(self.n_scratch * BLOCK);
        // One relaxed load per tape run decides whether blocks carry a
        // profiling accumulator; the disabled path is branch-identical
        // to the uninstrumented VM apart from one predictable `Option`
        // test per instruction.
        if profile::enabled() {
            let mut lb = LocalBlock::new();
            let mut off = 0;
            while off < out.len() {
                let len = BLOCK.min(out.len() - off);
                self.run_block(
                    leaves,
                    ileaves,
                    start + off,
                    &mut out[off..off + len],
                    &mut file,
                    Some(&mut lb),
                );
                off += len;
            }
            lb.flush();
        } else {
            let mut off = 0;
            while off < out.len() {
                let len = BLOCK.min(out.len() - off);
                self.run_block(
                    leaves,
                    ileaves,
                    start + off,
                    &mut out[off..off + len],
                    &mut file,
                    None,
                );
                off += len;
            }
        }
        scratch.put_file(file);
    }

    /// Execute one block (`out.len() <= BLOCK`). With `prof` set, each
    /// instruction's wall time and element count accumulate under its
    /// [`OpClass`] (flushed by the caller once per tape run).
    unsafe fn run_block(
        &self,
        leaves: &[LeafBind],
        ileaves: &[ILeafBind],
        start: usize,
        out: &mut [f64],
        file: &mut [f64],
        mut prof: Option<&mut LocalBlock>,
    ) {
        let len = out.len();
        let out_ptr = out.as_mut_ptr();
        let file_ptr = file.as_mut_ptr();
        // SAFETY (whole loop): the compiler guarantees the registers of
        // one instruction are pairwise distinct (an operand register is
        // allocated while `dst` is live, and register 0 never doubles as
        // an operand), so the mutable `dst` slice never aliases a source
        // slice; leaf buffers are caller-guaranteed live and disjoint
        // from the output and the register file.
        let bk = self.bk;
        for ins in &self.instrs {
            let t0 = if prof.is_some() { Some(Instant::now()) } else { None };
            match *ins {
                Instr::LoadContiguous { dst, leaf, base } => {
                    let o = reg_mut(out_ptr, file_ptr, dst, len);
                    backend::load_contiguous(leaf_slice(leaves, leaf), base, start, o);
                }
                Instr::LoadSplat { dst, leaf, idx } => {
                    reg_mut(out_ptr, file_ptr, dst, len).fill(leaf_slice(leaves, leaf)[idx]);
                }
                Instr::LoadBroadcast { dst, leaf, view } => {
                    let o = reg_mut(out_ptr, file_ptr, dst, len);
                    backend::load_broadcast(leaf_slice(leaves, leaf), &view, start, o);
                }
                Instr::LoadStrided { dst, leaf, view } => {
                    let o = reg_mut(out_ptr, file_ptr, dst, len);
                    backend::load_strided(leaf_slice(leaves, leaf), &view, start, o);
                }
                Instr::LoadModulo { dst, leaf, view } => {
                    let o = reg_mut(out_ptr, file_ptr, dst, len);
                    backend::load_modulo(leaf_slice(leaves, leaf), &view, start, o);
                }
                Instr::LoadGather { dst, leaf, idx, base } => {
                    let o = reg_mut(out_ptr, file_ptr, dst, len);
                    let src = leaf_slice(leaves, leaf);
                    let ix = ileaf_slice(ileaves, idx);
                    let s = base + start;
                    bk.load_gather(o, src, &ix[s..s + len]);
                }
                Instr::LoadConst { dst, val } => {
                    reg_mut(out_ptr, file_ptr, dst, len).fill(val);
                }
                Instr::LoadIota { dst } => {
                    let o = reg_mut(out_ptr, file_ptr, dst, len);
                    for (k, x) in o.iter_mut().enumerate() {
                        *x = (start + k) as f64;
                    }
                }
                Instr::Bin { op, dst, rhs } => {
                    let d = reg_mut(out_ptr, file_ptr, dst, len);
                    let s = reg_ref(out_ptr, file_ptr, rhs, len);
                    bk.bin_inplace(op, d, s);
                }
                Instr::BinConst { op, dst, val } => {
                    bk.bin_scalar_inplace(op, reg_mut(out_ptr, file_ptr, dst, len), val);
                }
                Instr::BinSplat { op, dst, leaf, idx } => {
                    let s = leaf_slice(leaves, leaf)[idx];
                    bk.bin_scalar_inplace(op, reg_mut(out_ptr, file_ptr, dst, len), s);
                }
                Instr::Un { op, dst } => {
                    bk.un_inplace(op, reg_mut(out_ptr, file_ptr, dst, len));
                }
                Instr::MulAdd { dst, a, b } => {
                    let d = reg_mut(out_ptr, file_ptr, dst, len);
                    let x = reg_ref(out_ptr, file_ptr, a, len);
                    let y = reg_ref(out_ptr, file_ptr, b, len);
                    bk.mul_add(d, x, y);
                }
                Instr::MulSub { dst, a, b } => {
                    let d = reg_mut(out_ptr, file_ptr, dst, len);
                    let x = reg_ref(out_ptr, file_ptr, a, len);
                    let y = reg_ref(out_ptr, file_ptr, b, len);
                    bk.mul_sub(d, x, y);
                }
                Instr::ScaleAddConst { dst, mul, add } => {
                    bk.scale_add_const(reg_mut(out_ptr, file_ptr, dst, len), mul, add);
                }
                Instr::Axpy { dst, sub, a, av, b, bv } => {
                    let op = if sub { BinOp::Sub } else { BinOp::Add };
                    let d = reg_mut(out_ptr, file_ptr, dst, len);
                    backend::axpy_pattern(
                        bk,
                        op,
                        leaf_slice(leaves, a),
                        &av,
                        leaf_slice(leaves, b),
                        &bv,
                        start,
                        d,
                    );
                }
            }
            if let (Some(p), Some(t0)) = (prof.as_deref_mut(), t0) {
                p.add(class_of(ins), len as u64, t0.elapsed().as_nanos() as u64);
            }
        }
    }
}

/// The profiling class of one tape instruction.
#[inline]
fn class_of(ins: &Instr) -> OpClass {
    match ins {
        Instr::LoadContiguous { .. } => OpClass::LoadContiguous,
        Instr::LoadSplat { .. } => OpClass::LoadSplat,
        Instr::LoadBroadcast { .. } => OpClass::LoadBroadcast,
        Instr::LoadStrided { .. } => OpClass::LoadStrided,
        Instr::LoadModulo { .. } => OpClass::LoadModulo,
        Instr::LoadGather { .. } => OpClass::LoadGather,
        Instr::LoadConst { .. } => OpClass::LoadConst,
        Instr::LoadIota { .. } => OpClass::LoadIota,
        Instr::Bin { .. } => OpClass::Bin,
        Instr::BinConst { .. } => OpClass::BinConst,
        Instr::BinSplat { .. } => OpClass::BinSplat,
        Instr::Un { .. } => OpClass::Un,
        Instr::MulAdd { .. } => OpClass::MulAdd,
        Instr::MulSub { .. } => OpClass::MulSub,
        Instr::ScaleAddConst { .. } => OpClass::ScaleAddConst,
        Instr::Axpy { .. } => OpClass::Axpy,
    }
}

/// Mutable view of register `r` for the current block.
///
/// # Safety
/// Caller guarantees `r` is in range and not simultaneously borrowed.
#[inline(always)]
unsafe fn reg_mut<'a>(out_ptr: *mut f64, file_ptr: *mut f64, r: Reg, len: usize) -> &'a mut [f64] {
    if r == 0 {
        std::slice::from_raw_parts_mut(out_ptr, len)
    } else {
        std::slice::from_raw_parts_mut(file_ptr.add((r as usize - 1) * BLOCK), len)
    }
}

/// Shared view of register `r` for the current block.
///
/// # Safety
/// Caller guarantees `r` is in range and not mutably borrowed.
#[inline(always)]
unsafe fn reg_ref<'a>(out_ptr: *mut f64, file_ptr: *mut f64, r: Reg, len: usize) -> &'a [f64] {
    if r == 0 {
        std::slice::from_raw_parts(out_ptr as *const f64, len)
    } else {
        std::slice::from_raw_parts(file_ptr.add((r as usize - 1) * BLOCK) as *const f64, len)
    }
}

/// Resolve a raw leaf binding to a slice.
///
/// # Safety
/// Caller guarantees the binding points at a live buffer.
#[inline(always)]
unsafe fn leaf_slice<'a>(leaves: &[LeafBind], l: u16) -> &'a [f64] {
    let (p, n) = leaves[l as usize];
    std::slice::from_raw_parts(p, n)
}

/// Resolve a raw i64 index-table binding to a slice.
///
/// # Safety
/// Caller guarantees the binding points at a live buffer.
#[inline(always)]
unsafe fn ileaf_slice<'a>(ileaves: &[ILeafBind], l: u16) -> &'a [i64] {
    let (p, n) = ileaves[l as usize];
    std::slice::from_raw_parts(p, n)
}

struct TapeBuilder {
    instrs: Vec<Instr>,
    /// Free-list of released registers (the liveness pass): a register is
    /// released the moment its consumer is emitted, so sibling subtrees
    /// reuse the same lanes and peak usage equals right-spine depth.
    free: Vec<Reg>,
    /// Next never-used register (1-based; 0 is the output register).
    next: usize,
    /// High-water mark: 1 + peak scratch registers in use.
    high: usize,
    n_leaves: usize,
    n_ileaves: usize,
}

impl TapeBuilder {
    fn alloc(&mut self) -> crate::Result<Reg> {
        if let Some(r) = self.free.pop() {
            return Ok(r);
        }
        if self.next >= MAX_REGS {
            return Err(crate::Error::Invalid(
                "fused tree too deep for the tape register file".into(),
            ));
        }
        let r = self.next as Reg;
        self.next += 1;
        self.high = self.high.max(self.next);
        Ok(r)
    }

    fn release(&mut self, r: Reg) {
        self.free.push(r);
    }

    fn saw_leaf(&mut self, l: u16) {
        self.n_leaves = self.n_leaves.max(l as usize + 1);
    }

    fn saw_ileaf(&mut self, l: u16) {
        self.n_ileaves = self.n_ileaves.max(l as usize + 1);
    }

    /// Emit code leaving the value of `t` in register `dst`.
    fn lower(&mut self, t: &KTree, dst: Reg) -> crate::Result<()> {
        match t {
            KTree::Const(c) => self.instrs.push(Instr::LoadConst { dst, val: *c }),
            KTree::Iota => self.instrs.push(Instr::LoadIota { dst }),
            KTree::Splat { leaf, idx } => {
                self.saw_leaf(*leaf);
                self.instrs.push(Instr::LoadSplat { dst, leaf: *leaf, idx: *idx });
            }
            KTree::Leaf { leaf, view } => {
                self.saw_leaf(*leaf);
                let ins = load_instr(dst, *leaf, view);
                self.instrs.push(ins);
            }
            KTree::Gather { src, idx, base } => {
                self.saw_leaf(*src);
                self.saw_ileaf(*idx);
                self.instrs.push(Instr::LoadGather { dst, leaf: *src, idx: *idx, base: *base });
            }
            KTree::Acc => {
                if dst != 0 {
                    return Err(crate::Error::Invalid(
                        "malformed plan: Acc leaf off the left spine".into(),
                    ));
                }
                // Register 0 already holds the accumulation base: no code.
            }
            KTree::Un(op, a) => {
                self.lower(a, dst)?;
                self.instrs.push(Instr::Un { op: *op, dst });
            }
            KTree::Bin(op, l, r) => {
                self.lower(l, dst)?;
                match &**r {
                    KTree::Const(c) => {
                        self.instrs.push(Instr::BinConst { op: *op, dst, val: *c })
                    }
                    KTree::Splat { leaf, idx } => {
                        self.saw_leaf(*leaf);
                        self.instrs.push(Instr::BinSplat {
                            op: *op,
                            dst,
                            leaf: *leaf,
                            idx: *idx,
                        });
                    }
                    KTree::Bin(BinOp::Mul, p, q)
                        if matches!(op, BinOp::Add | BinOp::Sub) =>
                    {
                        if let Some((al, av, bl, bv)) = axpy_leaves(p, q) {
                            self.saw_leaf(al);
                            self.saw_leaf(bl);
                            self.instrs.push(Instr::Axpy {
                                dst,
                                sub: *op == BinOp::Sub,
                                a: al,
                                av,
                                b: bl,
                                bv,
                            });
                        } else {
                            let ra = self.alloc()?;
                            self.lower(p, ra)?;
                            let rb = self.alloc()?;
                            self.lower(q, rb)?;
                            self.instrs.push(if *op == BinOp::Add {
                                Instr::MulAdd { dst, a: ra, b: rb }
                            } else {
                                Instr::MulSub { dst, a: ra, b: rb }
                            });
                            self.release(rb);
                            self.release(ra);
                        }
                    }
                    _ => {
                        let rr = self.alloc()?;
                        self.lower(r, rr)?;
                        self.instrs.push(Instr::Bin { op: *op, dst, rhs: rr });
                        self.release(rr);
                    }
                }
            }
        }
        Ok(())
    }
}

/// Classify a leaf view into its monomorphised load instruction.
fn load_instr(dst: Reg, leaf: u16, view: &View) -> Instr {
    if view.is_contiguous() {
        Instr::LoadContiguous { dst, leaf, base: view.base }
    } else if view.modulo.is_some() {
        Instr::LoadModulo { dst, leaf, view: *view }
    } else if view.col_stride == 0 && view.row_stride == 0 {
        Instr::LoadSplat { dst, leaf, idx: view.base }
    } else if view.col_stride == 0 {
        Instr::LoadBroadcast { dst, leaf, view: *view }
    } else {
        Instr::LoadStrided { dst, leaf, view: *view }
    }
}

/// Rank-1-update operand match on leaf-indexed trees (the tape analogue
/// of [`axpy_operands`]; the conditions are kept identical so both
/// executors special-case exactly the same trees).
fn axpy_leaves(p: &KTree, q: &KTree) -> Option<(u16, View, u16, View)> {
    let classify = |t: &KTree| match t {
        KTree::Leaf { leaf, view } => Some((*leaf, *view)),
        _ => None,
    };
    let (pl, pv) = classify(p)?;
    let (ql, qv) = classify(q)?;
    let is_bcast = |v: &View| v.col_stride == 0 && v.modulo.is_none();
    let is_row = |v: &View| v.col_stride == 1;
    if is_bcast(&pv) && is_row(&qv) {
        Some((pl, pv, ql, qv))
    } else if is_bcast(&qv) && is_row(&pv) {
        Some((ql, qv, pl, pv))
    } else {
        None
    }
}

/// Post-pass peepholes: merge `dst *= m; dst += c` (and the `-= c`
/// form) into one [`Instr::ScaleAddConst`] pass. The arithmetic is the
/// same two rounded operations, just one block traversal.
fn peephole(instrs: Vec<Instr>) -> Vec<Instr> {
    let mut out: Vec<Instr> = Vec::with_capacity(instrs.len());
    for ins in instrs {
        let last = out.last().copied();
        match (last, ins) {
            (
                Some(Instr::BinConst { op: BinOp::Mul, dst: d1, val: mul }),
                Instr::BinConst { op: op2, dst: d2, val: c },
            ) if d1 == d2 && matches!(op2, BinOp::Add | BinOp::Sub) => {
                let add = if op2 == BinOp::Sub { -c } else { c };
                out.pop();
                out.push(Instr::ScaleAddConst { dst: d2, mul, add });
            }
            (_, ins) => out.push(ins),
        }
    }
    out
}

/// A compiled fused kernel with its leaf buffers bound: the engine-side
/// tape (the serving layer binds leaves per request instead, through
/// [`TapeProgram::run_range_raw`]).
pub struct Tape {
    prog: TapeProgram,
    /// Keeps the leaf buffers alive; `raw` below points into them.
    _leaves: Vec<Arc<Vec<f64>>>,
    raw: Vec<LeafBind>,
    /// Index tables of fused gather leaves; `iraw` points into them.
    _ileaves: Vec<Arc<Vec<i64>>>,
    iraw: Vec<ILeafBind>,
}

// SAFETY: the raw bindings point into the heap buffers of the Arcs held
// by `_leaves`/`_ileaves`, which live (and never move) as long as the
// Tape; all access through them is read-only.
unsafe impl Send for Tape {}
unsafe impl Sync for Tape {}

impl Tape {
    /// Compile an executable fused tree into a tape running on the
    /// process-wide [`backend::active`] backend.
    pub fn compile(fx: &FExec) -> crate::Result<Tape> {
        Self::compile_with(fx, backend::active())
    }

    /// As [`Tape::compile`], against an explicit kernel backend.
    pub fn compile_with(fx: &FExec, bk: &'static dyn Backend) -> crate::Result<Tape> {
        let mut leaves: Vec<Arc<Vec<f64>>> = Vec::new();
        let mut ileaves: Vec<Arc<Vec<i64>>> = Vec::new();
        let kt = fexec_to_ktree(fx, &mut leaves, &mut ileaves)?;
        let prog = TapeProgram::compile_with(&kt, bk)?;
        let raw = leaves.iter().map(|a| (a.as_ptr(), a.len())).collect();
        let iraw = ileaves.iter().map(|a| (a.as_ptr(), a.len())).collect();
        Ok(Tape { prog, _leaves: leaves, raw, _ileaves: ileaves, iraw })
    }

    /// Lower an [`FTree`] and compile it — the engine's per-step entry
    /// (one compile, then every chunk of every block replays the tape).
    pub fn from_ftree(tree: &FTree) -> crate::Result<Tape> {
        Tape::compile(&lower(tree)?)
    }

    /// As [`Tape::from_ftree`], against an explicit kernel backend (the
    /// engine threads its context's selection here).
    pub fn from_ftree_with(tree: &FTree, bk: &'static dyn Backend) -> crate::Result<Tape> {
        Tape::compile_with(&lower(tree)?, bk)
    }

    /// The kernel backend this tape runs through.
    pub fn backend(&self) -> &'static dyn Backend {
        self.prog.backend()
    }

    /// Execute over output indices `[start, start + out.len())`.
    pub fn run_range(&self, start: usize, out: &mut [f64], scratch: &mut Scratch) {
        // SAFETY: `raw`/`iraw` point into buffers owned by this Tape,
        // alive for the duration of the call and disjoint from `out`
        // (the engine writes steps into freshly allocated buffers).
        unsafe { self.prog.run_range_raw(&self.raw, &self.iraw, start, out, scratch) }
    }

    pub fn program(&self) -> &TapeProgram {
        &self.prog
    }
}

fn fexec_to_ktree(
    fx: &FExec,
    leaves: &mut Vec<Arc<Vec<f64>>>,
    ileaves: &mut Vec<Arc<Vec<i64>>>,
) -> crate::Result<KTree> {
    let push_leaf = |leaves: &mut Vec<Arc<Vec<f64>>>, data: &Arc<Vec<f64>>| -> crate::Result<u16> {
        if leaves.len() >= u16::MAX as usize {
            return Err(crate::Error::Invalid(
                "fused tree has too many leaves for the tape VM".into(),
            ));
        }
        leaves.push(data.clone());
        Ok((leaves.len() - 1) as u16)
    };
    Ok(match fx {
        FExec::Leaf { data, view } => {
            KTree::Leaf { leaf: push_leaf(leaves, data)?, view: *view }
        }
        FExec::Gather { data, idx, base } => {
            if ileaves.len() >= u16::MAX as usize {
                return Err(crate::Error::Invalid(
                    "fused tree has too many index tables for the tape VM".into(),
                ));
            }
            // The gather loaders read through raw slices: reject an
            // out-of-range index table up front so a bad index is a
            // clean Error::Invalid (exactly what the materialising
            // Gather step guarantees), never a panic inside a shared
            // pool worker. The verdict is memoized by buffer identity —
            // the engine recompiles per force, and re-scanning the same
            // immutable table every CG iteration would double the
            // spmv's index traffic.
            let n = data.len();
            if !gather_check_lookup(idx, n) {
                if idx.iter().any(|&v| v < 0 || v as usize >= n) {
                    return Err(crate::Error::Invalid(format!(
                        "gather index out of range (source length {n})"
                    )));
                }
                gather_check_insert(idx, n);
            }
            let src = push_leaf(leaves, data)?;
            ileaves.push(idx.clone());
            KTree::Gather { src, idx: (ileaves.len() - 1) as u16, base: *base }
        }
        FExec::Const(c) => KTree::Const(*c),
        FExec::Iota => KTree::Iota,
        FExec::Acc => KTree::Acc,
        FExec::Bin(op, a, b) => KTree::Bin(
            *op,
            Box::new(fexec_to_ktree(a, leaves, ileaves)?),
            Box::new(fexec_to_ktree(b, leaves, ileaves)?),
        ),
        FExec::Un(op, a) => KTree::Un(*op, Box::new(fexec_to_ktree(a, leaves, ileaves)?)),
    })
}

// ---------------------------------------------------------------------
// Segmented tape executor (CSR row-pointer semantics)
// ---------------------------------------------------------------------
//
// `out[r] = red over tape(segp[r] .. segp[r+1])`: the fused tree is
// evaluated over a flat nnz index space and folded per variable-length
// segment. Three execution paths, all bit-identical (they share the
// `RedOp::fold_segment_chunk` association contract):
//
//  * **blocked** — the general path: the tape fills ≤BLOCK register
//    blocks of the segment's value stream, the segmented fold consumes
//    them (`fold_segment_chunk`).
//  * **fused `GatherMulSegSum`** — when the tree is exactly the spmv
//    inner loop `Sum(contiguous_vals * gather(x, idx))`, a
//    superinstruction runs `acc += vals[k] * x[idx[k]]` per row with no
//    intermediate block at all, replicating `fold_slice`'s 4-lane
//    association so the result stays bit-identical to the blocked path.
//  * **contiguity runs** — the `arbb_spmv2` exploit: when the caller
//    hints it, the index table is scanned once (at compile/capture) for
//    runs of consecutive columns; the value stream is then produced by
//    streaming `vals[k..] * x[col..]` without the per-element gather.

/// The fused spmv superinstruction's operands: `vals` and `x` are f64
/// leaf bindings, `idx` an index-table binding.
#[derive(Debug, Clone, Copy)]
struct GatherMulSegSum {
    vals: u16,
    vals_base: usize,
    x: u16,
    idx: u16,
    idx_base: usize,
}

/// Per-row contiguity runs detected in a gather index table: globally
/// ordered runs `(run_k, run_col, run_len)` with per-row pointers
/// `run_ptr` (runs never cross row boundaries).
#[derive(Debug, Default)]
pub struct RunTable {
    run_ptr: Vec<i64>,
    run_k: Vec<i64>,
    run_col: Vec<i64>,
    run_len: Vec<i64>,
}

impl RunTable {
    /// Number of runs detected.
    pub fn n_runs(&self) -> usize {
        self.run_k.len()
    }
}

/// A compiled segmented-reduction kernel: the general tape plus the
/// optional fused/run fast paths selected at compile time. Run tables
/// are `Arc`ed so the process-wide memo can share one detection across
/// recompiles of the same bound CSR (the engine re-plans per force).
#[derive(Debug)]
pub struct SegTape {
    prog: TapeProgram,
    red: RedOp,
    fused: Option<GatherMulSegSum>,
    runs: Option<Arc<RunTable>>,
}

impl SegTape {
    /// Compile a leaf-indexed fused tree into a segmented kernel,
    /// pattern-matching the spmv superinstruction; runs on the
    /// process-wide [`backend::active`] backend.
    pub fn compile(tree: &KTree, red: RedOp) -> crate::Result<SegTape> {
        Self::compile_with(tree, red, backend::active())
    }

    /// As [`SegTape::compile`], against an explicit kernel backend.
    pub fn compile_with(
        tree: &KTree,
        red: RedOp,
        bk: &'static dyn Backend,
    ) -> crate::Result<SegTape> {
        let prog = TapeProgram::compile_with(tree, bk)?;
        let fused = if matches!(red, RedOp::Sum) { match_gather_mul(tree) } else { None };
        Ok(SegTape { prog, red, fused, runs: None })
    }

    /// The kernel backend this segmented tape runs through.
    pub fn backend(&self) -> &'static dyn Backend {
        self.prog.backend()
    }

    /// The underlying leaf-abstract tape (the blocked path's program).
    pub fn program(&self) -> &TapeProgram {
        &self.prog
    }

    pub fn n_leaves(&self) -> usize {
        self.prog.n_leaves()
    }

    pub fn n_ileaves(&self) -> usize {
        self.prog.n_ileaves()
    }

    /// Whether the fused `GatherMulSegSum` superinstruction was matched.
    pub fn is_fused(&self) -> bool {
        self.fused.is_some()
    }

    /// Index-table binding of the fused gather, if matched (callers use
    /// it to hand [`SegTape::detect_runs`] the right table).
    pub fn fused_idx(&self) -> Option<u16> {
        self.fused.map(|f| f.idx)
    }

    /// Whether the contiguity-run path is active.
    pub fn has_runs(&self) -> bool {
        self.runs.is_some()
    }

    /// Scan the fused gather's index table for runs of consecutive
    /// columns (the paper's `arbb_spmv2` preprocessing, moved from
    /// `bind_csr` into the executor so every frontend benefits) and
    /// switch the run path on. Returns the fraction of elements inside
    /// runs of length ≥ 2 — the matrix-contiguity statistic of §3.2.
    /// No-op (returns 0) unless the fused pattern matched. Empty
    /// segments and trailing empty segments produce no runs and fold to
    /// the identity.
    pub fn detect_runs(&mut self, idx: &[i64], segp: &[i64]) -> f64 {
        let f = match self.fused {
            Some(f) => f,
            None => return 0.0,
        };
        let rows = segp.len().saturating_sub(1);
        let mut rt = RunTable::default();
        rt.run_ptr.reserve(rows + 1);
        rt.run_ptr.push(0);
        let mut in_runs = 0usize;
        let mut total = 0usize;
        for r in 0..rows {
            let (s, e) = (segp[r] as usize, segp[r + 1] as usize);
            total += e - s;
            let mut k = s;
            while k < e {
                let col = idx[f.idx_base + k];
                let mut len = 1usize;
                while k + len < e && idx[f.idx_base + k + len] == col + len as i64 {
                    len += 1;
                }
                rt.run_k.push(k as i64);
                rt.run_col.push(col);
                rt.run_len.push(len as i64);
                if len >= 2 {
                    in_runs += len;
                }
                k += len;
            }
            rt.run_ptr.push(rt.run_k.len() as i64);
        }
        self.runs = Some(Arc::new(rt));
        if total == 0 {
            0.0
        } else {
            in_runs as f64 / total as f64
        }
    }

    /// Attach a previously detected run table (memoized reuse; no-op
    /// unless the fused pattern matched, since the run path needs its
    /// operands).
    pub fn attach_runs(&mut self, rt: Arc<RunTable>) {
        if self.fused.is_some() {
            self.runs = Some(rt);
        }
    }

    /// Force a dispatch path chosen by the plan explorer. All paths are
    /// bit-identical, so this only changes cost: `Blocked` drops the
    /// fused superinstruction and any run table, `Fused` drops the run
    /// table, `Runs`/`Auto` keep whatever is attached. Downgrades
    /// gracefully: forcing `Fused`/`Runs` when the spmv pattern never
    /// matched leaves the blocked path in place.
    pub fn force_path(&mut self, path: super::tuning::SegPath) {
        use super::tuning::SegPath;
        match path {
            SegPath::Auto | SegPath::Runs => {}
            SegPath::Fused => self.runs = None,
            SegPath::Blocked => {
                self.fused = None;
                self.runs = None;
            }
        }
    }

    /// The dispatch path [`SegTape::run_rows_raw`] will take, as its
    /// profiling opcode class.
    pub fn path_class(&self) -> OpClass {
        if self.fused.is_some() {
            if self.runs.is_some() {
                OpClass::SegRuns
            } else {
                OpClass::SegFused
            }
        } else {
            OpClass::SegBlocked
        }
    }

    /// The active run table, if any.
    pub fn runs(&self) -> Option<&Arc<RunTable>> {
        self.runs.as_ref()
    }

    /// Reduce segments `[row0, row0 + out.len())`, writing one value per
    /// segment. Rows are independent, so panel-parallel callers get
    /// results bit-identical to a serial sweep.
    ///
    /// # Safety
    ///
    /// As [`TapeProgram::run_range_raw`]; additionally `segp` must be
    /// monotone with `segp[r+1]` within every bound leaf's gather range.
    pub unsafe fn run_rows_raw(
        &self,
        leaves: &[LeafBind],
        ileaves: &[ILeafBind],
        segp: &[i64],
        row0: usize,
        out: &mut [f64],
        scratch: &mut Scratch,
    ) {
        // When profiling, one sample per call covering the whole row
        // panel: class = dispatched path, elems = nnz swept. (On the
        // blocked path this is inclusive of the inner tape's own
        // per-instruction samples.)
        let t0 = profile::enabled().then(Instant::now);
        let class = if let Some(f) = self.fused {
            if let Some(rt) = &self.runs {
                self.run_rows_runs(leaves, f, rt, segp, row0, out, scratch);
                OpClass::SegRuns
            } else {
                self.run_rows_fused(leaves, ileaves, f, segp, row0, out);
                OpClass::SegFused
            }
        } else {
            self.run_rows_blocked(leaves, ileaves, segp, row0, out, scratch);
            OpClass::SegBlocked
        };
        if let Some(t0) = t0 {
            let r1 = row0 + out.len();
            let nnz = segp[r1].saturating_sub(segp[row0]).max(0) as u64;
            profile::record_sample(class, nnz, t0.elapsed().as_nanos() as u64);
        }
    }

    /// General path: tape-fill ≤BLOCK value blocks, segmented-fold them.
    unsafe fn run_rows_blocked(
        &self,
        leaves: &[LeafBind],
        ileaves: &[ILeafBind],
        segp: &[i64],
        row0: usize,
        out: &mut [f64],
        scratch: &mut Scratch,
    ) {
        let bk = self.prog.backend();
        let mut buf = scratch.take();
        for (j, ov) in out.iter_mut().enumerate() {
            let r = row0 + j;
            let (s, e) = (segp[r] as usize, segp[r + 1] as usize);
            let mut acc = self.red.identity();
            let mut k = s;
            while k < e {
                let l = BLOCK.min(e - k);
                self.prog.run_range_raw(leaves, ileaves, k, &mut buf[..l], scratch);
                acc = bk.fold_segment_chunk(self.red, acc, &buf[..l]);
                k += l;
            }
            *ov = acc;
        }
        scratch.put(buf);
    }

    /// Fused spmv path: `acc += vals[k] * x[idx[k]]` per row through
    /// [`Backend::gather_mul_sum`], whose 4-lane association replicates
    /// `RedOp::Sum::fold_slice` so the result is bit-identical to the
    /// blocked path without materialising the product stream.
    unsafe fn run_rows_fused(
        &self,
        leaves: &[LeafBind],
        ileaves: &[ILeafBind],
        f: GatherMulSegSum,
        segp: &[i64],
        row0: usize,
        out: &mut [f64],
    ) {
        let bk = self.prog.backend();
        let vals = leaf_slice(leaves, f.vals);
        let x = leaf_slice(leaves, f.x);
        let ix = ileaf_slice(ileaves, f.idx);
        for (j, ov) in out.iter_mut().enumerate() {
            let r = row0 + j;
            let (s, e) = (segp[r] as usize, segp[r + 1] as usize);
            let mut acc = self.red.identity();
            let mut k = s;
            while k < e {
                let l = BLOCK.min(e - k);
                acc += bk.gather_mul_sum(
                    &vals[f.vals_base + k..f.vals_base + k + l],
                    x,
                    &ix[f.idx_base + k..f.idx_base + k + l],
                );
                k += l;
            }
            *ov = acc;
        }
    }

    /// Contiguity-run path (`arbb_spmv2`): the product stream is built
    /// by streaming `vals[k..] * x[col..]` per run — no index loads —
    /// then folded exactly like the blocked path.
    #[allow(clippy::too_many_arguments)]
    unsafe fn run_rows_runs(
        &self,
        leaves: &[LeafBind],
        f: GatherMulSegSum,
        rt: &RunTable,
        segp: &[i64],
        row0: usize,
        out: &mut [f64],
        scratch: &mut Scratch,
    ) {
        let bk = self.prog.backend();
        let vals = leaf_slice(leaves, f.vals);
        let x = leaf_slice(leaves, f.x);
        let mut buf = scratch.take();
        for (j, ov) in out.iter_mut().enumerate() {
            let r = row0 + j;
            let (s, e) = (segp[r] as usize, segp[r + 1] as usize);
            let mut t = rt.run_ptr[r] as usize;
            let mut acc = self.red.identity();
            let mut k = s;
            while k < e {
                let l = BLOCK.min(e - k);
                let chunk = &mut buf[..l];
                let mut filled = 0usize;
                while filled < l {
                    let rk = rt.run_k[t] as usize;
                    let rl = rt.run_len[t] as usize;
                    let rc = rt.run_col[t] as usize;
                    let off = k + filled - rk;
                    let take = (rl - off).min(l - filled);
                    let vs = &vals[f.vals_base + k + filled..f.vals_base + k + filled + take];
                    let xs = &x[rc + off..rc + off + take];
                    bk.mul_streams(&mut chunk[filled..filled + take], vs, xs);
                    filled += take;
                    if off + take == rl {
                        t += 1;
                    }
                }
                acc = bk.fold_segment_chunk(self.red, acc, chunk);
                k += l;
            }
            *ov = acc;
        }
        scratch.put(buf);
    }
}

/// Match the spmv inner-loop pattern `contiguous_leaf * gather` (either
/// operand order — multiplication is bitwise commutative on f64).
fn match_gather_mul(tree: &KTree) -> Option<GatherMulSegSum> {
    let (p, q) = match tree {
        KTree::Bin(BinOp::Mul, p, q) => (&**p, &**q),
        _ => return None,
    };
    let pick = |a: &KTree, b: &KTree| -> Option<GatherMulSegSum> {
        match (a, b) {
            (KTree::Leaf { leaf, view }, KTree::Gather { src, idx, base })
                if view.is_contiguous() =>
            {
                Some(GatherMulSegSum {
                    vals: *leaf,
                    vals_base: view.base,
                    x: *src,
                    idx: *idx,
                    idx_base: *base,
                })
            }
            _ => None,
        }
    };
    pick(p, q).or_else(|| pick(q, p))
}

/// Bounded process-wide memo of detected contiguity-run tables, keyed
/// by buffer identity (`Arc::ptr_eq` against the live index-table and
/// row-pointer buffers — i64 container buffers are immutable once
/// bound). The interactive engine re-plans and recompiles on every
/// force; without this, every `arbb_spmv2`/CG iteration would redo the
/// O(nnz) run scan that cached serving plans amortise at capture.
struct RunMemoEntry {
    idx: Weak<Vec<i64>>,
    segp: Weak<Vec<i64>>,
    idx_base: usize,
    runs: Arc<RunTable>,
}

const RUN_MEMO_CAP: usize = 16;

fn run_memo() -> &'static Mutex<Vec<RunMemoEntry>> {
    static MEMO: OnceLock<Mutex<Vec<RunMemoEntry>>> = OnceLock::new();
    MEMO.get_or_init(|| Mutex::new(Vec::new()))
}

fn run_memo_lookup(
    idx: &Arc<Vec<i64>>,
    segp: &Arc<Vec<i64>>,
    idx_base: usize,
) -> Option<Arc<RunTable>> {
    let memo = run_memo().lock().unwrap();
    for e in memo.iter() {
        if e.idx_base == idx_base {
            if let (Some(i), Some(s)) = (e.idx.upgrade(), e.segp.upgrade()) {
                if Arc::ptr_eq(&i, idx) && Arc::ptr_eq(&s, segp) {
                    return Some(e.runs.clone());
                }
            }
        }
    }
    None
}

fn run_memo_insert(
    idx: &Arc<Vec<i64>>,
    segp: &Arc<Vec<i64>>,
    idx_base: usize,
    runs: Arc<RunTable>,
) {
    let mut memo = run_memo().lock().unwrap();
    memo.retain(|e| e.idx.strong_count() > 0 && e.segp.strong_count() > 0);
    if memo.len() >= RUN_MEMO_CAP {
        memo.remove(0);
    }
    memo.push(RunMemoEntry {
        idx: Arc::downgrade(idx),
        segp: Arc::downgrade(segp),
        idx_base,
        runs,
    });
}

/// Memo of gather index tables already range-checked against a source
/// length (buffer-identity keyed like the run memo; i64 container
/// buffers are immutable once bound, so a verdict never goes stale).
struct GatherCheckEntry {
    idx: Weak<Vec<i64>>,
    src_len: usize,
}

const GATHER_CHECK_CAP: usize = 32;

fn gather_check_memo() -> &'static Mutex<Vec<GatherCheckEntry>> {
    static MEMO: OnceLock<Mutex<Vec<GatherCheckEntry>>> = OnceLock::new();
    MEMO.get_or_init(|| Mutex::new(Vec::new()))
}

fn gather_check_lookup(idx: &Arc<Vec<i64>>, src_len: usize) -> bool {
    let memo = gather_check_memo().lock().unwrap();
    memo.iter().any(|e| {
        e.src_len == src_len
            && match e.idx.upgrade() {
                Some(i) => Arc::ptr_eq(&i, idx),
                None => false,
            }
    })
}

fn gather_check_insert(idx: &Arc<Vec<i64>>, src_len: usize) {
    let mut memo = gather_check_memo().lock().unwrap();
    memo.retain(|e| e.idx.strong_count() > 0);
    if memo.len() >= GATHER_CHECK_CAP {
        memo.remove(0);
    }
    memo.push(GatherCheckEntry { idx: Arc::downgrade(idx), src_len });
}

/// Engine-side segmented kernel with its buffers bound: compiled once
/// per step, replayed per row panel (the serving layer rebinds leaves
/// per request through [`SegTape::run_rows_raw`] instead).
pub struct BoundSeg {
    seg: SegTape,
    _leaves: Vec<Arc<Vec<f64>>>,
    raw: Vec<LeafBind>,
    _ileaves: Vec<Arc<Vec<i64>>>,
    iraw: Vec<ILeafBind>,
}

// SAFETY: as for `Tape` — the raw bindings point into Arc-held buffers
// owned by this value, and all access is read-only.
unsafe impl Send for BoundSeg {}
unsafe impl Sync for BoundSeg {}

impl BoundSeg {
    /// Lower and compile a segmented-reduction operand tree. When
    /// `detect_contiguity` is set and the fused spmv pattern matched,
    /// the gather index table is scanned for contiguity runs
    /// (`arbb_spmv2`) — once per bound CSR, via the run-table memo.
    pub fn from_ftree(
        tree: &FTree,
        red: RedOp,
        segp: &Arc<Vec<i64>>,
        detect_contiguity: bool,
    ) -> crate::Result<BoundSeg> {
        Self::from_fexec(&lower(tree)?, red, segp, detect_contiguity)
    }

    /// As [`BoundSeg::from_ftree`], against an explicit kernel backend.
    pub fn from_ftree_with(
        tree: &FTree,
        red: RedOp,
        segp: &Arc<Vec<i64>>,
        detect_contiguity: bool,
        bk: &'static dyn Backend,
    ) -> crate::Result<BoundSeg> {
        Self::from_fexec_with(&lower(tree)?, red, segp, detect_contiguity, bk)
    }

    /// As [`BoundSeg::from_ftree`], from an already-lowered tree.
    pub fn from_fexec(
        fx: &FExec,
        red: RedOp,
        segp: &Arc<Vec<i64>>,
        detect_contiguity: bool,
    ) -> crate::Result<BoundSeg> {
        Self::from_fexec_with(fx, red, segp, detect_contiguity, backend::active())
    }

    /// As [`BoundSeg::from_fexec`], against an explicit kernel backend.
    pub fn from_fexec_with(
        fx: &FExec,
        red: RedOp,
        segp: &Arc<Vec<i64>>,
        detect_contiguity: bool,
        bk: &'static dyn Backend,
    ) -> crate::Result<BoundSeg> {
        let mut leaves: Vec<Arc<Vec<f64>>> = Vec::new();
        let mut ileaves: Vec<Arc<Vec<i64>>> = Vec::new();
        let kt = fexec_to_ktree(fx, &mut leaves, &mut ileaves)?;
        let mut seg = SegTape::compile_with(&kt, red, bk)?;
        if detect_contiguity {
            if let (Some(fi), Some(f)) = (seg.fused_idx(), seg.fused) {
                let idx = ileaves[fi as usize].clone();
                match run_memo_lookup(&idx, segp, f.idx_base) {
                    Some(rt) => seg.attach_runs(rt),
                    None => {
                        seg.detect_runs(&idx, segp);
                        if let Some(rt) = seg.runs() {
                            run_memo_insert(&idx, segp, f.idx_base, rt.clone());
                        }
                    }
                }
            }
        }
        let raw = leaves.iter().map(|a| (a.as_ptr(), a.len())).collect();
        let iraw = ileaves.iter().map(|a| (a.as_ptr(), a.len())).collect();
        Ok(BoundSeg { seg, _leaves: leaves, raw, _ileaves: ileaves, iraw })
    }

    /// Reduce segments `[row0, row0 + out.len())`.
    pub fn run_rows(&self, segp: &[i64], row0: usize, out: &mut [f64], scratch: &mut Scratch) {
        // SAFETY: bindings point into Arc-held buffers owned by self,
        // disjoint from `out` (a freshly allocated step output).
        unsafe { self.seg.run_rows_raw(&self.raw, &self.iraw, segp, row0, out, scratch) }
    }

    pub fn seg(&self) -> &SegTape {
        &self.seg
    }
}

/// Tree-interpreter reference for segmented reduction: the bit-exact
/// comparator every [`SegTape`] path must reproduce (same blocked
/// evaluation, same `fold_segment_chunk` association — only the value
/// production goes through [`eval_range`] instead of the tape VM).
pub fn seg_reduce_rows_ref(
    fx: &FExec,
    red: RedOp,
    segp: &[i64],
    row0: usize,
    out: &mut [f64],
    scratch: &mut Scratch,
) {
    let mut buf = scratch.take();
    for (j, ov) in out.iter_mut().enumerate() {
        let r = row0 + j;
        let (s, e) = (segp[r] as usize, segp[r + 1] as usize);
        let mut acc = red.identity();
        let mut k = s;
        while k < e {
            let l = BLOCK.min(e - k);
            eval_range(fx, k, &mut buf[..l], scratch);
            acc = red.fold_segment_chunk(acc, &buf[..l]);
            k += l;
        }
        *ov = acc;
    }
    scratch.put(buf);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaf(data: Vec<f64>, view: View) -> FExec {
        FExec::Leaf { data: Arc::new(data), view }
    }

    /// Evaluate through both executors and require bit-identical output.
    fn eval_both(fx: &FExec, start: usize, init: &[f64]) -> Vec<f64> {
        let mut tree_out = init.to_vec();
        eval_range(fx, start, &mut tree_out, &mut Scratch::default());
        let tape = Tape::compile(fx).unwrap();
        let mut tape_out = init.to_vec();
        tape.run_range(start, &mut tape_out, &mut Scratch::default());
        assert_eq!(tree_out.len(), tape_out.len());
        for (i, (a, b)) in tree_out.iter().zip(&tape_out).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "tape diverges from tree at {i}: {a:?} vs {b:?}"
            );
        }
        tree_out
    }

    #[test]
    fn eval_contiguous_add() {
        let a = leaf(vec![1.0, 2.0, 3.0, 4.0], View::identity(4));
        let b = leaf(vec![10.0, 20.0, 30.0, 40.0], View::identity(4));
        let fx = FExec::Bin(BinOp::Add, Box::new(a), Box::new(b));
        let out = eval_both(&fx, 0, &[0.0; 4]);
        assert_eq!(out, vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn eval_scalar_rhs() {
        let a = leaf(vec![1.0, 2.0], View::identity(2));
        let fx = FExec::Bin(BinOp::Mul, Box::new(a), Box::new(FExec::Const(3.0)));
        let out = eval_both(&fx, 0, &[0.0; 2]);
        assert_eq!(out, vec![3.0, 6.0]);
    }

    #[test]
    fn eval_strided_view() {
        // even elements of an 8-vector
        let v = View { base: 0, row_stride: 0, col_stride: 2, out_cols: 4, modulo: None };
        let fx = leaf((0..8).map(|x| x as f64).collect(), v);
        let out = eval_both(&fx, 0, &[0.0; 4]);
        assert_eq!(out, vec![0.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn eval_modulo_view() {
        let v = View { base: 0, row_stride: 4, col_stride: 1, out_cols: 4, modulo: Some(2) };
        let fx = leaf(vec![7.0, 9.0], v);
        let out = eval_both(&fx, 0, &[0.0; 8]);
        assert_eq!(out, vec![7.0, 9.0, 7.0, 9.0, 7.0, 9.0, 7.0, 9.0]);
    }

    #[test]
    fn eval_range_with_offset() {
        // Evaluating a sub-range must agree with evaluating the whole.
        let n = 100;
        let data: Vec<f64> = (0..n).map(|x| (x * x) as f64).collect();
        let fx = FExec::Un(
            UnOp::Sqrt,
            Box::new(leaf(data.clone(), View::identity(10))),
        );
        let init = vec![0.0; n];
        let full = eval_both(&fx, 0, &init);
        let part = eval_both(&fx, 25, &[0.0; 30]);
        assert_eq!(&full[25..55], part.as_slice());
    }

    #[test]
    fn eval_iota() {
        let fx = FExec::Iota;
        let out = eval_both(&fx, 10, &[0.0; 5]);
        assert_eq!(out, vec![10.0, 11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn acc_placement() {
        let ok = FExec::Bin(
            BinOp::Add,
            Box::new(FExec::Acc),
            Box::new(FExec::Const(1.0)),
        );
        assert!(ok.acc_placement_ok());
        let bad = FExec::Bin(
            BinOp::Add,
            Box::new(FExec::Const(1.0)),
            Box::new(FExec::Acc),
        );
        assert!(!bad.acc_placement_ok());
    }

    #[test]
    fn eval_accumulate_inplace() {
        // out starts as base; fx = Acc + leaf
        let addend = leaf(vec![1.0, 2.0, 3.0], View::identity(3));
        let fx = FExec::Bin(BinOp::Add, Box::new(FExec::Acc), Box::new(addend));
        let out = eval_both(&fx, 0, &[10.0, 20.0, 30.0]);
        assert_eq!(out, vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn lower_unmaterialised_leaf_is_error_not_panic() {
        use crate::coordinator::node::{Node, Op};
        use crate::coordinator::shape::{DType, Shape};
        // A pending node with no storage: lowering a plan that references
        // it must produce Error::Invalid (a serving worker must survive).
        let pending = Node::new(Op::Iota(4), Shape::D1(4), DType::F64);
        let tree = FTree::Leaf { node: pending, view: View::identity(4) };
        match lower(&tree) {
            Err(crate::Error::Invalid(msg)) => {
                assert!(msg.contains("not materialised"), "{msg}")
            }
            other => panic!("expected Error::Invalid, got {other:?}"),
        }
    }

    #[test]
    fn lower_rejects_acc_off_left_spine() {
        let bad = FTree::Bin(
            BinOp::Add,
            Box::new(FTree::Const(1.0)),
            Box::new(FTree::Acc),
        );
        assert!(lower(&bad).is_err());
    }

    #[test]
    fn tape_rejects_acc_off_left_spine() {
        let bad = FExec::Bin(
            BinOp::Add,
            Box::new(FExec::Const(1.0)),
            Box::new(FExec::Acc),
        );
        assert!(Tape::compile(&bad).is_err());
    }

    #[test]
    fn blocks_cross_boundaries() {
        let n = BLOCK * 3 + 17;
        let data: Vec<f64> = (0..n).map(|x| x as f64).collect();
        let fx = FExec::Bin(
            BinOp::Add,
            Box::new(leaf(data.clone(), View::identity(n))),
            Box::new(FExec::Const(0.5)),
        );
        let init = vec![0.0; n];
        let out = eval_both(&fx, 0, &init);
        for i in [0, 1, BLOCK - 1, BLOCK, 2 * BLOCK + 5, n - 1] {
            assert_eq!(out[i], i as f64 + 0.5);
        }
    }

    #[test]
    fn tape_left_deep_chain_reuses_one_register() {
        // ((((a + b) + c) + d) + e): every rhs leaf is released before
        // the next is lowered, so one scratch register suffices.
        let n = 8;
        let mk = |s: f64| leaf(vec![s; n], View::identity(n));
        let mut fx = mk(1.0);
        for k in 2..=5 {
            fx = FExec::Bin(BinOp::Add, Box::new(fx), Box::new(mk(k as f64)));
        }
        let tape = Tape::compile(&fx).unwrap();
        assert_eq!(tape.program().n_scratch_regs(), 1, "free-list must reuse registers");
        let out = eval_both(&fx, 0, &[0.0; 8]);
        assert_eq!(out[0], 15.0);
    }

    #[test]
    fn tape_emits_scale_add_const_peephole() {
        // a * 2 + 1  →  Load; ScaleAddConst
        let fx = FExec::Bin(
            BinOp::Add,
            Box::new(FExec::Bin(
                BinOp::Mul,
                Box::new(leaf(vec![1.0, 2.0, 3.0], View::identity(3))),
                Box::new(FExec::Const(2.0)),
            )),
            Box::new(FExec::Const(1.0)),
        );
        let tape = Tape::compile(&fx).unwrap();
        assert_eq!(tape.program().n_instrs(), 2, "{:?}", tape.program().instrs());
        assert!(matches!(tape.program().instrs()[1], Instr::ScaleAddConst { .. }));
        let out = eval_both(&fx, 0, &[0.0; 3]);
        assert_eq!(out, vec![3.0, 5.0, 7.0]);
    }

    #[test]
    fn tape_emits_mul_add_superinstruction() {
        // acc + x*y with non-axpy views → MulAdd, not Mul + Add.
        let n = 6;
        let x = leaf((0..n).map(|v| v as f64).collect(), View::identity(n));
        let y = leaf((0..n).map(|v| (v * 2) as f64).collect(), View::identity(n));
        let base = leaf(vec![1.0; n], View::identity(n));
        let fx = FExec::Bin(
            BinOp::Add,
            Box::new(base),
            Box::new(FExec::Bin(BinOp::Mul, Box::new(x), Box::new(y))),
        );
        let tape = Tape::compile(&fx).unwrap();
        assert!(
            tape.program()
                .instrs()
                .iter()
                .any(|i| matches!(i, Instr::MulAdd { .. })),
            "{:?}",
            tape.program().instrs()
        );
        let out = eval_both(&fx, 0, &[0.0; 6]);
        assert_eq!(out[3], 1.0 + 3.0 * 6.0);
    }

    #[test]
    fn tape_emits_axpy_superinstruction() {
        // colbcast(a) * row(b) under Add → the rank-1-update instruction.
        let oc = 8;
        let a = leaf(
            vec![2.0, 3.0],
            View { base: 0, row_stride: 1, col_stride: 0, out_cols: oc, modulo: None },
        );
        let b = leaf(
            (0..16).map(|v| v as f64).collect(),
            View { base: 0, row_stride: 8, col_stride: 1, out_cols: oc, modulo: None },
        );
        let fx = FExec::Bin(
            BinOp::Add,
            Box::new(FExec::Const(0.0)),
            Box::new(FExec::Bin(BinOp::Mul, Box::new(a), Box::new(b))),
        );
        let tape = Tape::compile(&fx).unwrap();
        assert!(
            tape.program().instrs().iter().any(|i| matches!(i, Instr::Axpy { .. })),
            "{:?}",
            tape.program().instrs()
        );
        let out = eval_both(&fx, 0, &[0.0; 16]);
        assert_eq!(out[1], 2.0); // row 0: 2.0 * b[1]
        assert_eq!(out[9], 3.0 * 9.0); // row 1: 3.0 * b[9]
    }

    #[test]
    fn tape_program_run_with_bound_leaves() {
        // The leaf-abstract entry: same program, rebound buffers.
        let kt = KTree::Bin(
            BinOp::Mul,
            Box::new(KTree::Leaf { leaf: 0, view: View::identity(4) }),
            Box::new(KTree::Splat { leaf: 1, idx: 0 }),
        );
        let prog = TapeProgram::compile(&kt).unwrap();
        assert_eq!(prog.n_leaves(), 2);
        let xs = [1.0, 2.0, 3.0, 4.0];
        let mut out = [0.0; 4];
        for s in [2.0, 10.0] {
            let scale = [s];
            prog.run_range(
                &[xs.as_slice(), scale.as_slice()],
                &[],
                0,
                &mut out,
                &mut Scratch::default(),
            );
            assert_eq!(out, [1.0 * s, 2.0 * s, 3.0 * s, 4.0 * s]);
        }
    }

    #[test]
    fn gather_leaf_tape_matches_tree() {
        // (a * gather(x, idx)): the spmv inner-loop element space.
        let nnz = BLOCK + 37; // cross a block boundary
        let a: Vec<f64> = (0..nnz).map(|k| (k % 13) as f64 - 6.0).collect();
        let x: Vec<f64> = (0..50).map(|k| (k * k) as f64).collect();
        let idx: Vec<i64> = (0..nnz).map(|k| ((k * 7) % 50) as i64).collect();
        let fx = FExec::Bin(
            BinOp::Mul,
            Box::new(leaf(a.clone(), View::identity(nnz))),
            Box::new(FExec::Gather {
                data: Arc::new(x.clone()),
                idx: Arc::new(idx.clone()),
                base: 0,
            }),
        );
        let out = eval_both(&fx, 0, &vec![0.0; nnz]);
        for k in [0usize, 1, BLOCK - 1, BLOCK, nnz - 1] {
            assert_eq!(out[k], a[k] * x[idx[k] as usize], "elem {k}");
        }
    }

    #[test]
    fn seg_tape_paths_are_bit_identical() {
        use crate::util::XorShift64;
        // Random CSR-ish structure with empty rows, a dense row longer
        // than one evaluation BLOCK (2048) — exercising every path's
        // intra-segment chunk carry — and a trailing all-zero row.
        let mut rng = XorShift64::new(42);
        let ncols = BLOCK + 452; // dense row spans 2 chunks
        let nrows = 40usize;
        let mut segp = vec![0i64];
        let mut idx: Vec<i64> = Vec::new();
        for r in 0..nrows {
            let nnz_r = match r {
                5 | 17 => 0,            // empty rows
                9 => ncols,             // dense row: one long run
                r if r == nrows - 1 => 0, // trailing all-zero row
                _ => rng.below(24),
            };
            let mut cols: Vec<i64> = Vec::new();
            if nnz_r == ncols {
                cols.extend(0..ncols as i64);
            } else {
                while cols.len() < nnz_r {
                    let c = rng.below(ncols) as i64;
                    if !cols.contains(&c) {
                        cols.push(c);
                    }
                }
                cols.sort_unstable();
            }
            idx.extend_from_slice(&cols);
            segp.push(idx.len() as i64);
        }
        let segp = Arc::new(segp);
        let nnz = idx.len();
        let vals: Vec<f64> = (0..nnz).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let x: Vec<f64> = (0..ncols).map(|_| rng.range_f64(-1.0, 1.0)).collect();

        let fx = FExec::Bin(
            BinOp::Mul,
            Box::new(leaf(vals.clone(), View::identity(nnz))),
            Box::new(FExec::Gather {
                data: Arc::new(x.clone()),
                idx: Arc::new(idx.clone()),
                base: 0,
            }),
        );
        let mut scratch = Scratch::default();
        // Reference: tree interpreter + segmented fold.
        let mut want = vec![0.0; nrows];
        seg_reduce_rows_ref(&fx, RedOp::Sum, &segp, 0, &mut want, &mut scratch);
        assert_eq!(want[5], 0.0, "empty row folds to the identity");
        assert_eq!(want[nrows - 1], 0.0, "trailing zero row folds to the identity");

        // Fused path.
        let fused = BoundSeg::from_fexec(&fx, RedOp::Sum, &segp, false).unwrap();
        assert!(fused.seg().is_fused());
        assert!(!fused.seg().has_runs());
        let mut got = vec![0.0; nrows];
        fused.run_rows(&segp, 0, &mut got, &mut scratch);
        for r in 0..nrows {
            assert_eq!(got[r].to_bits(), want[r].to_bits(), "fused row {r}");
        }

        // Run path.
        let runs = BoundSeg::from_fexec(&fx, RedOp::Sum, &segp, true).unwrap();
        assert!(runs.seg().has_runs());
        got.fill(-1.0);
        runs.run_rows(&segp, 0, &mut got, &mut scratch);
        for r in 0..nrows {
            assert_eq!(got[r].to_bits(), want[r].to_bits(), "runs row {r}");
        }

        // Blocked path (break the fused match with a no-op Add 0.0).
        let blocked_fx = FExec::Bin(
            BinOp::Add,
            Box::new(fx.clone()),
            Box::new(FExec::Const(0.0)),
        );
        let mut want2 = vec![0.0; nrows];
        seg_reduce_rows_ref(&blocked_fx, RedOp::Sum, &segp, 0, &mut want2, &mut scratch);
        let blocked = BoundSeg::from_fexec(&blocked_fx, RedOp::Sum, &segp, false).unwrap();
        assert!(!blocked.seg().is_fused());
        got.fill(-1.0);
        blocked.run_rows(&segp, 0, &mut got, &mut scratch);
        for r in 0..nrows {
            assert_eq!(got[r].to_bits(), want2[r].to_bits(), "blocked row {r}");
        }

        // Panel split must not change any row (rows are independent).
        let mid = nrows / 2;
        let mut lo = vec![0.0; mid];
        let mut hi = vec![0.0; nrows - mid];
        fused.run_rows(&segp, 0, &mut lo, &mut scratch);
        fused.run_rows(&segp, mid, &mut hi, &mut scratch);
        for r in 0..nrows {
            let v = if r < mid { lo[r] } else { hi[r - mid] };
            assert_eq!(v.to_bits(), want[r].to_bits(), "panelled row {r}");
        }
    }

    #[test]
    fn seg_tape_non_sum_reduction_uses_blocked_path() {
        // max over segments through the general path.
        let vals = vec![1.0, 5.0, -2.0, 7.0, 0.5];
        let segp = Arc::new(vec![0i64, 2, 2, 5]);
        let fx = leaf(vals, View::identity(5));
        let b = BoundSeg::from_fexec(&fx, RedOp::Max, &segp, false).unwrap();
        assert!(!b.seg().is_fused());
        let mut out = vec![0.0; 3];
        b.run_rows(&segp, 0, &mut out, &mut Scratch::default());
        assert_eq!(out, vec![5.0, f64::NEG_INFINITY, 7.0]);
    }
}
