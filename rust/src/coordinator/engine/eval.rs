//! Block-wise evaluation of fused expression trees: a reference tree
//! interpreter plus the production tape compiler + register VM.
//!
//! A lowered [`FExec`] tree is evaluated over a range of flat output
//! indices in cache-resident blocks: each operator processes one block
//! (`BLOCK` elements) at a time, so fused chains make a single pass over
//! main memory regardless of chain length — the optimisation ArBB's JIT
//! performs when it compiles a captured closure.
//!
//! Two executors share that blocking discipline:
//!
//!  * [`eval_range`] — the original recursive **tree interpreter**. It
//!    re-walks the boxed tree for every block; retained as the reference
//!    semantics (the property tests compare the tape VM against it
//!    bit-for-bit) and as the ablation baseline.
//!  * [`Tape`] — the **tape compiler + register VM**. The tree is
//!    lowered post-order, once, into a flat `Vec<Instr>` over virtual
//!    block registers; a free-list register allocator reuses registers
//!    as their live ranges end, so the peak register count is the depth
//!    of the deepest right spine, not the operator count. Leaf loads
//!    are monomorphised per view shape ([`Instr::LoadContiguous`] /
//!    `LoadBroadcast` / `LoadStrided` / `LoadModulo` / `LoadSplat`)
//!    replacing the generic dispatch of `fill_view`, and the hot
//!    operator shapes collapse into fused superinstructions
//!    ([`Instr::MulAdd`], [`Instr::Axpy`], [`Instr::ScaleAddConst`])
//!    that subsume the tree interpreter's hand-matched rank-1-update
//!    special case and remove whole block passes. See EXPERIMENTS.md
//!    §"Tape VM" for the design notes and microbenchmark results.

use std::sync::Arc;

use crate::coordinator::ops::{BinOp, UnOp};
use crate::coordinator::plan::FTree;
use crate::coordinator::shape::View;

/// Elements per evaluation block (16 KiB of f64).
///
/// Tuning rationale (EXPERIMENTS.md §"Tape VM"): the block must be small
/// enough that the output block plus the tape's live registers (typically
/// 1–3, worst case the right-spine depth of the fused tree) stay
/// L1/L2-resident — at 2048 elements four live blocks occupy 64 KiB —
/// yet large enough that per-block dispatch (one linear scan of the
/// instruction tape, or one tree walk for the reference interpreter)
/// amortises to noise against the ~2048-iteration inner loops. Halving
/// it doubles dispatch overhead with no locality gain; doubling it
/// spills deep chains' register files out of L1.
pub const BLOCK: usize = 2048;

/// Execution-side fused tree: leaves are resolved to concrete buffers.
/// `Send + Sync` so parallel workers can share it.
#[derive(Debug, Clone)]
pub enum FExec {
    Leaf { data: Arc<Vec<f64>>, view: View },
    Const(f64),
    Iota,
    /// In-place accumulation marker: the output block already holds the
    /// base values; evaluating `Acc` is a no-op. Only valid as the
    /// left-most leaf (validated at lowering).
    Acc,
    Bin(BinOp, Box<FExec>, Box<FExec>),
    Un(UnOp, Box<FExec>),
}

impl FExec {
    /// Validate the `Acc` placement invariant: `Acc` may only appear on
    /// the left spine (so left-first evaluation never overwrites the base
    /// values before they are consumed).
    pub fn acc_placement_ok(&self) -> bool {
        fn scan(t: &FExec, leftmost: bool) -> bool {
            match t {
                FExec::Acc => leftmost,
                FExec::Bin(_, l, r) => scan(l, leftmost) && scan(r, false),
                FExec::Un(_, a) => scan(a, leftmost),
                _ => true,
            }
        }
        scan(self, true)
    }
}

/// Resolve an [`FTree`] into an executable [`FExec`], reading leaf
/// storages (all dependencies have been materialised by earlier steps).
///
/// A malformed plan — a leaf whose producing step is missing, or an
/// `Acc` marker off the left spine — is an [`crate::Error::Invalid`],
/// not a panic: a serving worker must survive a bad plan.
pub fn lower(tree: &FTree) -> crate::Result<FExec> {
    let fx = lower_inner(tree)?;
    if !fx.acc_placement_ok() {
        return Err(crate::Error::Invalid(
            "malformed plan: Acc leaf off the left spine".into(),
        ));
    }
    Ok(fx)
}

fn lower_inner(tree: &FTree) -> crate::Result<FExec> {
    Ok(match tree {
        FTree::Leaf { node, view } => {
            let data = node.data().ok_or_else(|| {
                crate::Error::Invalid(format!(
                    "malformed plan: leaf {} not materialised at lowering",
                    node.id
                ))
            })?;
            FExec::Leaf { data: data.as_f64().clone(), view: *view }
        }
        FTree::ScalarLeaf { node } => {
            let data = node.data().ok_or_else(|| {
                crate::Error::Invalid(format!(
                    "malformed plan: scalar leaf {} not materialised",
                    node.id
                ))
            })?;
            FExec::Const(data.as_f64()[0])
        }
        FTree::Const(c) => FExec::Const(*c),
        FTree::Iota => FExec::Iota,
        FTree::Acc => FExec::Acc,
        FTree::Bin(op, a, b) => {
            FExec::Bin(*op, Box::new(lower_inner(a)?), Box::new(lower_inner(b)?))
        }
        FTree::Un(op, a) => FExec::Un(*op, Box::new(lower_inner(a)?)),
    })
}

/// Scratch block pool: one per worker; blocks are recycled across
/// operators and evaluation calls.
#[derive(Default)]
pub struct Scratch {
    free: Vec<Vec<f64>>,
    /// Cached tape register file (tapes never nest on one thread, so a
    /// single cached file suffices; it grows to the largest request and
    /// is reused allocation-free from then on).
    file: Option<Vec<f64>>,
}

impl Scratch {
    pub fn take(&mut self) -> Vec<f64> {
        self.free.pop().unwrap_or_else(|| vec![0.0; BLOCK])
    }

    pub fn put(&mut self, b: Vec<f64>) {
        if self.free.len() < 64 {
            self.free.push(b);
        }
    }

    /// Take the thread-cached tape register file, grown to at least
    /// `len` elements. Steady state performs no allocation.
    pub fn take_file(&mut self, len: usize) -> Vec<f64> {
        let mut f = self.file.take().unwrap_or_default();
        if f.len() < len {
            f.resize(len, 0.0);
        }
        f
    }

    /// Return a register file; the largest seen so far is kept.
    pub fn put_file(&mut self, f: Vec<f64>) {
        match &self.file {
            Some(cur) if cur.len() >= f.len() => {}
            _ => self.file = Some(f),
        }
    }
}

thread_local! {
    static TLS_SCRATCH: std::cell::RefCell<Scratch> =
        std::cell::RefCell::new(Scratch::default());
}

/// Run `f` with this thread's persistent scratch pool (blocks survive
/// across steps and chunks — allocating per chunk showed up in profiles;
/// EXPERIMENTS.md §Perf iteration 2).
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    TLS_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

// ---------------------------------------------------------------------
// Reference tree interpreter
// ---------------------------------------------------------------------

/// Evaluate `fx` for flat output indices `[start, start+out.len())`.
///
/// The caller supplies arbitrary ranges (chunks); evaluation proceeds in
/// `BLOCK`-sized sub-blocks internally.
pub fn eval_range(fx: &FExec, start: usize, out: &mut [f64], scratch: &mut Scratch) {
    let mut off = 0;
    while off < out.len() {
        let len = BLOCK.min(out.len() - off);
        eval_block(fx, start + off, &mut out[off..off + len], scratch);
        off += len;
    }
}

/// Evaluate one block (`out.len() <= BLOCK`).
fn eval_block(fx: &FExec, start: usize, out: &mut [f64], scratch: &mut Scratch) {
    match fx {
        FExec::Const(c) => out.fill(*c),
        FExec::Iota => {
            for (k, o) in out.iter_mut().enumerate() {
                *o = (start + k) as f64;
            }
        }
        FExec::Acc => {
            // The output block already holds the accumulation base.
        }
        FExec::Leaf { data, view } => fill_view(data, view, start, out),
        FExec::Un(op, a) => {
            eval_block(a, start, out, scratch);
            op.apply_slice_inplace(out);
        }
        FExec::Bin(op, l, r) => {
            // Left into `out`, right into scratch, combine in place.
            eval_block(l, start, out, scratch);
            match &**r {
                FExec::Const(c) => op.apply_slice_scalar_inplace(out, *c),
                // Rank-1-update pattern (the arbb_mxm2a/2b hot loop):
                // out ±= colbcast(a) * rowleaf(b) — one fused pass, no
                // temporaries (EXPERIMENTS.md §Perf iteration 3).
                FExec::Bin(BinOp::Mul, p, q)
                    if matches!(op, BinOp::Add | BinOp::Sub)
                        && axpy_operands(p, q).is_some() =>
                {
                    let (da, va, db, vb) = axpy_operands(p, q).unwrap();
                    axpy_pattern(*op, da, va, db, vb, start, out);
                }
                _ => {
                    let mut tmp = scratch.take();
                    let t = &mut tmp[..out.len()];
                    eval_block(r, start, t, scratch);
                    op.apply_slices_inplace(out, t);
                    scratch.put(tmp);
                }
            }
        }
    }
}

/// Match the `colbcast(a) * rowleaf(b)` operand pair of a rank-1 update:
/// `p` broadcasts along columns (`col_stride == 0`, no modulo), `q` is a
/// unit-stride row view (possibly cyclic — `repeat_row` composes to a
/// modulo view). Returns the leaves in (bcast, row) order, commuting if
/// needed.
#[allow(clippy::type_complexity)]
fn axpy_operands<'a>(
    p: &'a FExec,
    q: &'a FExec,
) -> Option<(&'a [f64], &'a View, &'a [f64], &'a View)> {
    let classify = |t: &'a FExec| match t {
        FExec::Leaf { data, view } => Some((data.as_slice(), view)),
        _ => None,
    };
    let (pa, pv) = classify(p)?;
    let (qa, qv) = classify(q)?;
    let is_bcast = |v: &View| v.col_stride == 0 && v.modulo.is_none();
    let is_row = |v: &View| v.col_stride == 1;
    if is_bcast(pv) && is_row(qv) {
        Some((pa, pv, qa, qv))
    } else if is_bcast(qv) && is_row(pv) {
        Some((qa, qv, pa, pv))
    } else {
        None
    }
}

/// `out[seg] op= a_r * b[seg]` per output-row segment.
fn axpy_pattern(
    op: BinOp,
    da: &[f64],
    va: &View,
    db: &[f64],
    vb: &View,
    start: usize,
    out: &mut [f64],
) {
    let oc = va.out_cols.max(1);
    let len = out.len();
    let mut pos = 0usize;
    let mut r = start / oc;
    let mut c = start % oc;
    while pos < len {
        let seg = (oc - c).min(len - pos);
        let f = da[va.base + r * va.row_stride];
        let f = if op == BinOp::Sub { -f } else { f };
        // source segment through vb (cs == 1), splitting at cyclic wraps
        let mut done = 0usize;
        while done < seg {
            let lin = r * vb.row_stride + (c + done);
            let (off, room) = match vb.modulo {
                Some(m) => (lin % m, m - lin % m),
                None => (lin, usize::MAX),
            };
            let take = room.min(seg - done);
            let src = &db[vb.base + off..vb.base + off + take];
            let dst = &mut out[pos + done..pos + done + take];
            for i in 0..take {
                dst[i] += f * src[i];
            }
            done += take;
        }
        pos += seg;
        r += 1;
        c = 0;
    }
}

// ---------------------------------------------------------------------
// Monomorphised leaf loaders
// ---------------------------------------------------------------------
//
// One function per view shape, classified once at tape-compile time
// (the reference interpreter's `fill_view` re-classifies per block and
// dispatches to the same loaders, keeping the two executors bit-exact).

/// Contiguous leaf: a single memcpy.
#[inline]
fn load_contiguous(data: &[f64], base: usize, start: usize, out: &mut [f64]) {
    let s = base + start;
    out.copy_from_slice(&data[s..s + out.len()]);
}

/// Column-broadcast leaf (`col_stride == 0`, no modulo): one constant
/// fill per output-row segment.
#[inline]
fn load_broadcast(data: &[f64], view: &View, start: usize, out: &mut [f64]) {
    let oc = view.out_cols.max(1);
    let len = out.len();
    let mut pos = 0usize;
    let mut r = start / oc;
    let mut c = start % oc;
    while pos < len {
        let seg = (oc - c).min(len - pos);
        out[pos..pos + seg].fill(data[view.base + r * view.row_stride]);
        pos += seg;
        r += 1;
        c = 0;
    }
}

/// Strided leaf (`col_stride >= 1`, no modulo): unit-stride row segments
/// memcpy, otherwise a strided gather per segment.
#[inline]
fn load_strided(data: &[f64], view: &View, start: usize, out: &mut [f64]) {
    let oc = view.out_cols.max(1);
    let len = out.len();
    let cs = view.col_stride;
    let mut pos = 0usize;
    let mut r = start / oc;
    let mut c = start % oc;
    while pos < len {
        let seg = (oc - c).min(len - pos);
        let s0 = view.base + r * view.row_stride + c * cs;
        let o = &mut out[pos..pos + seg];
        if cs == 1 {
            o.copy_from_slice(&data[s0..s0 + seg]);
        } else {
            let mut s = s0;
            for x in o.iter_mut() {
                *x = data[s];
                s += cs;
            }
        }
        pos += seg;
        r += 1;
        c = 0;
    }
}

/// Cyclic leaf (`repeat` views): wrap by subtraction — col_stride never
/// exceeds the period by construction (compose scales both).
#[inline]
fn load_modulo(data: &[f64], view: &View, start: usize, out: &mut [f64]) {
    let oc = view.out_cols.max(1);
    let len = out.len();
    let cs = view.col_stride;
    let m = match view.modulo {
        Some(m) => m,
        None => return,
    };
    let mut pos = 0usize;
    let mut r = start / oc;
    let mut c = start % oc;
    while pos < len {
        let seg = (oc - c).min(len - pos);
        let mut lin = (r * view.row_stride + c * cs) % m;
        for x in out[pos..pos + seg].iter_mut() {
            *x = data[view.base + lin];
            lin += cs;
            if lin >= m {
                lin %= m;
            }
        }
        pos += seg;
        r += 1;
        c = 0;
    }
}

/// Gather a block through an affine view: classify the view shape and
/// dispatch to the matching monomorphised loader.
fn fill_view(data: &[f64], view: &View, start: usize, out: &mut [f64]) {
    if view.is_contiguous() {
        load_contiguous(data, view.base, start, out);
    } else if view.modulo.is_some() {
        load_modulo(data, view, start, out);
    } else if view.col_stride == 0 {
        load_broadcast(data, view, start, out);
    } else {
        load_strided(data, view, start, out);
    }
}

impl BinOp {
    /// `out[i] = op(out[i], s)` — scalar right operand, in place.
    #[inline]
    pub fn apply_slice_scalar_inplace(self, out: &mut [f64], s: f64) {
        match self {
            BinOp::Add => out.iter_mut().for_each(|x| *x += s),
            BinOp::Sub => out.iter_mut().for_each(|x| *x -= s),
            BinOp::Mul => out.iter_mut().for_each(|x| *x *= s),
            BinOp::Div => {
                let inv = 1.0 / s;
                out.iter_mut().for_each(|x| *x *= inv)
            }
            BinOp::Min => out.iter_mut().for_each(|x| *x = x.min(s)),
            BinOp::Max => out.iter_mut().for_each(|x| *x = x.max(s)),
        }
    }
}

// ---------------------------------------------------------------------
// Tape compiler + register VM
// ---------------------------------------------------------------------

/// Virtual block-register index. Register 0 is the output block; higher
/// registers are `BLOCK`-sized lanes of a per-thread scratch file.
pub type Reg = u16;

/// Hard cap on virtual registers per tape. The free-list allocator keeps
/// the peak at the right-spine depth of the fused tree, which the
/// planner bounds at [`crate::coordinator::plan::MAX_FUSE_OPS`]; the cap
/// only guards hand-built trees.
const MAX_REGS: usize = 4096;

/// A raw leaf binding (`ptr`, `len`), the allocation-free way to hand a
/// resolved buffer set to [`TapeProgram::run_range_raw`].
pub type LeafBind = (*const f64, usize);

/// Leaf-indexed fused tree: the tape compiler's input. Both the engine's
/// [`FExec`] (Arc-resolved leaves) and the serving layer's graph-free
/// trees lower into this, keeping buffer resolution out of the compiler.
#[derive(Debug, Clone)]
pub enum KTree {
    Leaf { leaf: u16, view: View },
    /// Broadcast of the single element `leaves[leaf][idx]`, bound at
    /// run time (serving scalar parameters resolve here).
    Splat { leaf: u16, idx: usize },
    Const(f64),
    Iota,
    Acc,
    Bin(BinOp, Box<KTree>, Box<KTree>),
    Un(UnOp, Box<KTree>),
}

/// One tape instruction. All instructions operate on the current block:
/// loads materialise a leaf segment into a register, operator
/// instructions mutate their `dst` register in place, and the fused
/// superinstructions (`MulAdd`/`MulSub`/`ScaleAddConst`/`Axpy`) combine
/// what the tree interpreter needs several block passes for into one.
#[derive(Debug, Clone, Copy)]
pub enum Instr {
    /// `dst <- leaf[base + i]` (contiguous view: one memcpy).
    LoadContiguous { dst: Reg, leaf: u16, base: usize },
    /// `dst <- broadcast(leaf[idx])`.
    LoadSplat { dst: Reg, leaf: u16, idx: usize },
    /// `dst <- leaf` through a column-broadcast view.
    LoadBroadcast { dst: Reg, leaf: u16, view: View },
    /// `dst <- leaf` through a strided (modulo-free) view.
    LoadStrided { dst: Reg, leaf: u16, view: View },
    /// `dst <- leaf` through a cyclic view.
    LoadModulo { dst: Reg, leaf: u16, view: View },
    /// `dst <- broadcast(val)`.
    LoadConst { dst: Reg, val: f64 },
    /// `dst[k] <- (start + k) as f64`.
    LoadIota { dst: Reg },
    /// `dst <- op(dst, rhs)`.
    Bin { op: BinOp, dst: Reg, rhs: Reg },
    /// `dst <- op(dst, val)`.
    BinConst { op: BinOp, dst: Reg, val: f64 },
    /// `dst <- op(dst, leaf[idx])` — runtime-bound scalar operand.
    BinSplat { op: BinOp, dst: Reg, leaf: u16, idx: usize },
    /// `dst <- op(dst)`.
    Un { op: UnOp, dst: Reg },
    /// `dst[i] += a[i] * b[i]` — one pass instead of mul-into-scratch
    /// plus add-from-scratch.
    MulAdd { dst: Reg, a: Reg, b: Reg },
    /// `dst[i] -= a[i] * b[i]`.
    MulSub { dst: Reg, a: Reg, b: Reg },
    /// `dst[i] = dst[i] * mul + add` — peephole of adjacent scalar
    /// multiply and add/subtract.
    ScaleAddConst { dst: Reg, mul: f64, add: f64 },
    /// Rank-1 update: `dst[seg] ±= a_row * b[seg]` with `a` a
    /// column-broadcast leaf and `b` a unit-stride row leaf — subsumes
    /// the tree interpreter's hand-matched special case.
    Axpy { dst: Reg, sub: bool, a: u16, av: View, b: u16, bv: View },
}

/// A compiled, leaf-abstract tape: the instruction stream plus register
/// and leaf counts. `Send + Sync`; bind leaves per run.
#[derive(Debug)]
pub struct TapeProgram {
    instrs: Vec<Instr>,
    /// Scratch registers beyond the output register (peak liveness after
    /// free-list reuse).
    n_scratch: usize,
    n_leaves: usize,
}

impl TapeProgram {
    /// Lower a leaf-indexed fused tree post-order into a flat tape.
    pub fn compile(tree: &KTree) -> crate::Result<TapeProgram> {
        let mut b = TapeBuilder {
            instrs: Vec::new(),
            free: Vec::new(),
            next: 1,
            high: 1,
            n_leaves: 0,
        };
        b.lower(tree, 0)?;
        let instrs = peephole(b.instrs);
        Ok(TapeProgram { instrs, n_scratch: b.high - 1, n_leaves: b.n_leaves })
    }

    pub fn n_instrs(&self) -> usize {
        self.instrs.len()
    }

    /// Scratch registers beyond the output register (peak liveness).
    pub fn n_scratch_regs(&self) -> usize {
        self.n_scratch
    }

    pub fn n_leaves(&self) -> usize {
        self.n_leaves
    }

    pub fn instrs(&self) -> &[Instr] {
        &self.instrs
    }

    /// Execute over output indices `[start, start + out.len())` with
    /// `leaves[i]` bound to the i-th leaf buffer.
    pub fn run_range(
        &self,
        leaves: &[&[f64]],
        start: usize,
        out: &mut [f64],
        scratch: &mut Scratch,
    ) {
        let raw: Vec<LeafBind> = leaves.iter().map(|s| (s.as_ptr(), s.len())).collect();
        // SAFETY: `raw` points into `leaves`, which outlive this call.
        unsafe { self.run_range_raw(&raw, start, out, scratch) }
    }

    /// Allocation-free entry: leaves are pre-resolved raw bindings (the
    /// serving replay arena recycles the binding vector across calls).
    ///
    /// # Safety
    ///
    /// Every `(ptr, len)` in `leaves` must describe a live, initialised
    /// f64 buffer for the duration of the call, none of which overlaps
    /// `out`.
    pub unsafe fn run_range_raw(
        &self,
        leaves: &[LeafBind],
        start: usize,
        out: &mut [f64],
        scratch: &mut Scratch,
    ) {
        debug_assert!(leaves.len() >= self.n_leaves, "tape run with too few leaf bindings");
        let mut file = scratch.take_file(self.n_scratch * BLOCK);
        let mut off = 0;
        while off < out.len() {
            let len = BLOCK.min(out.len() - off);
            self.run_block(leaves, start + off, &mut out[off..off + len], &mut file);
            off += len;
        }
        scratch.put_file(file);
    }

    /// Execute one block (`out.len() <= BLOCK`).
    unsafe fn run_block(
        &self,
        leaves: &[LeafBind],
        start: usize,
        out: &mut [f64],
        file: &mut [f64],
    ) {
        let len = out.len();
        let out_ptr = out.as_mut_ptr();
        let file_ptr = file.as_mut_ptr();
        // SAFETY (whole loop): the compiler guarantees the registers of
        // one instruction are pairwise distinct (an operand register is
        // allocated while `dst` is live, and register 0 never doubles as
        // an operand), so the mutable `dst` slice never aliases a source
        // slice; leaf buffers are caller-guaranteed live and disjoint
        // from the output and the register file.
        for ins in &self.instrs {
            match *ins {
                Instr::LoadContiguous { dst, leaf, base } => {
                    let o = reg_mut(out_ptr, file_ptr, dst, len);
                    load_contiguous(leaf_slice(leaves, leaf), base, start, o);
                }
                Instr::LoadSplat { dst, leaf, idx } => {
                    reg_mut(out_ptr, file_ptr, dst, len).fill(leaf_slice(leaves, leaf)[idx]);
                }
                Instr::LoadBroadcast { dst, leaf, view } => {
                    let o = reg_mut(out_ptr, file_ptr, dst, len);
                    load_broadcast(leaf_slice(leaves, leaf), &view, start, o);
                }
                Instr::LoadStrided { dst, leaf, view } => {
                    let o = reg_mut(out_ptr, file_ptr, dst, len);
                    load_strided(leaf_slice(leaves, leaf), &view, start, o);
                }
                Instr::LoadModulo { dst, leaf, view } => {
                    let o = reg_mut(out_ptr, file_ptr, dst, len);
                    load_modulo(leaf_slice(leaves, leaf), &view, start, o);
                }
                Instr::LoadConst { dst, val } => {
                    reg_mut(out_ptr, file_ptr, dst, len).fill(val);
                }
                Instr::LoadIota { dst } => {
                    let o = reg_mut(out_ptr, file_ptr, dst, len);
                    for (k, x) in o.iter_mut().enumerate() {
                        *x = (start + k) as f64;
                    }
                }
                Instr::Bin { op, dst, rhs } => {
                    let d = reg_mut(out_ptr, file_ptr, dst, len);
                    let s = reg_ref(out_ptr, file_ptr, rhs, len);
                    op.apply_slices_inplace(d, s);
                }
                Instr::BinConst { op, dst, val } => {
                    op.apply_slice_scalar_inplace(reg_mut(out_ptr, file_ptr, dst, len), val);
                }
                Instr::BinSplat { op, dst, leaf, idx } => {
                    let s = leaf_slice(leaves, leaf)[idx];
                    op.apply_slice_scalar_inplace(reg_mut(out_ptr, file_ptr, dst, len), s);
                }
                Instr::Un { op, dst } => {
                    op.apply_slice_inplace(reg_mut(out_ptr, file_ptr, dst, len));
                }
                Instr::MulAdd { dst, a, b } => {
                    let d = reg_mut(out_ptr, file_ptr, dst, len);
                    let x = reg_ref(out_ptr, file_ptr, a, len);
                    let y = reg_ref(out_ptr, file_ptr, b, len);
                    for i in 0..len {
                        d[i] += x[i] * y[i];
                    }
                }
                Instr::MulSub { dst, a, b } => {
                    let d = reg_mut(out_ptr, file_ptr, dst, len);
                    let x = reg_ref(out_ptr, file_ptr, a, len);
                    let y = reg_ref(out_ptr, file_ptr, b, len);
                    for i in 0..len {
                        d[i] -= x[i] * y[i];
                    }
                }
                Instr::ScaleAddConst { dst, mul, add } => {
                    for x in reg_mut(out_ptr, file_ptr, dst, len).iter_mut() {
                        *x = *x * mul + add;
                    }
                }
                Instr::Axpy { dst, sub, a, av, b, bv } => {
                    let op = if sub { BinOp::Sub } else { BinOp::Add };
                    let d = reg_mut(out_ptr, file_ptr, dst, len);
                    axpy_pattern(
                        op,
                        leaf_slice(leaves, a),
                        &av,
                        leaf_slice(leaves, b),
                        &bv,
                        start,
                        d,
                    );
                }
            }
        }
    }
}

/// Mutable view of register `r` for the current block.
///
/// # Safety
/// Caller guarantees `r` is in range and not simultaneously borrowed.
#[inline(always)]
unsafe fn reg_mut<'a>(out_ptr: *mut f64, file_ptr: *mut f64, r: Reg, len: usize) -> &'a mut [f64] {
    if r == 0 {
        std::slice::from_raw_parts_mut(out_ptr, len)
    } else {
        std::slice::from_raw_parts_mut(file_ptr.add((r as usize - 1) * BLOCK), len)
    }
}

/// Shared view of register `r` for the current block.
///
/// # Safety
/// Caller guarantees `r` is in range and not mutably borrowed.
#[inline(always)]
unsafe fn reg_ref<'a>(out_ptr: *mut f64, file_ptr: *mut f64, r: Reg, len: usize) -> &'a [f64] {
    if r == 0 {
        std::slice::from_raw_parts(out_ptr as *const f64, len)
    } else {
        std::slice::from_raw_parts(file_ptr.add((r as usize - 1) * BLOCK) as *const f64, len)
    }
}

/// Resolve a raw leaf binding to a slice.
///
/// # Safety
/// Caller guarantees the binding points at a live buffer.
#[inline(always)]
unsafe fn leaf_slice<'a>(leaves: &[LeafBind], l: u16) -> &'a [f64] {
    let (p, n) = leaves[l as usize];
    std::slice::from_raw_parts(p, n)
}

struct TapeBuilder {
    instrs: Vec<Instr>,
    /// Free-list of released registers (the liveness pass): a register is
    /// released the moment its consumer is emitted, so sibling subtrees
    /// reuse the same lanes and peak usage equals right-spine depth.
    free: Vec<Reg>,
    /// Next never-used register (1-based; 0 is the output register).
    next: usize,
    /// High-water mark: 1 + peak scratch registers in use.
    high: usize,
    n_leaves: usize,
}

impl TapeBuilder {
    fn alloc(&mut self) -> crate::Result<Reg> {
        if let Some(r) = self.free.pop() {
            return Ok(r);
        }
        if self.next >= MAX_REGS {
            return Err(crate::Error::Invalid(
                "fused tree too deep for the tape register file".into(),
            ));
        }
        let r = self.next as Reg;
        self.next += 1;
        self.high = self.high.max(self.next);
        Ok(r)
    }

    fn release(&mut self, r: Reg) {
        self.free.push(r);
    }

    fn saw_leaf(&mut self, l: u16) {
        self.n_leaves = self.n_leaves.max(l as usize + 1);
    }

    /// Emit code leaving the value of `t` in register `dst`.
    fn lower(&mut self, t: &KTree, dst: Reg) -> crate::Result<()> {
        match t {
            KTree::Const(c) => self.instrs.push(Instr::LoadConst { dst, val: *c }),
            KTree::Iota => self.instrs.push(Instr::LoadIota { dst }),
            KTree::Splat { leaf, idx } => {
                self.saw_leaf(*leaf);
                self.instrs.push(Instr::LoadSplat { dst, leaf: *leaf, idx: *idx });
            }
            KTree::Leaf { leaf, view } => {
                self.saw_leaf(*leaf);
                let ins = load_instr(dst, *leaf, view);
                self.instrs.push(ins);
            }
            KTree::Acc => {
                if dst != 0 {
                    return Err(crate::Error::Invalid(
                        "malformed plan: Acc leaf off the left spine".into(),
                    ));
                }
                // Register 0 already holds the accumulation base: no code.
            }
            KTree::Un(op, a) => {
                self.lower(a, dst)?;
                self.instrs.push(Instr::Un { op: *op, dst });
            }
            KTree::Bin(op, l, r) => {
                self.lower(l, dst)?;
                match &**r {
                    KTree::Const(c) => {
                        self.instrs.push(Instr::BinConst { op: *op, dst, val: *c })
                    }
                    KTree::Splat { leaf, idx } => {
                        self.saw_leaf(*leaf);
                        self.instrs.push(Instr::BinSplat {
                            op: *op,
                            dst,
                            leaf: *leaf,
                            idx: *idx,
                        });
                    }
                    KTree::Bin(BinOp::Mul, p, q)
                        if matches!(op, BinOp::Add | BinOp::Sub) =>
                    {
                        if let Some((al, av, bl, bv)) = axpy_leaves(p, q) {
                            self.saw_leaf(al);
                            self.saw_leaf(bl);
                            self.instrs.push(Instr::Axpy {
                                dst,
                                sub: *op == BinOp::Sub,
                                a: al,
                                av,
                                b: bl,
                                bv,
                            });
                        } else {
                            let ra = self.alloc()?;
                            self.lower(p, ra)?;
                            let rb = self.alloc()?;
                            self.lower(q, rb)?;
                            self.instrs.push(if *op == BinOp::Add {
                                Instr::MulAdd { dst, a: ra, b: rb }
                            } else {
                                Instr::MulSub { dst, a: ra, b: rb }
                            });
                            self.release(rb);
                            self.release(ra);
                        }
                    }
                    _ => {
                        let rr = self.alloc()?;
                        self.lower(r, rr)?;
                        self.instrs.push(Instr::Bin { op: *op, dst, rhs: rr });
                        self.release(rr);
                    }
                }
            }
        }
        Ok(())
    }
}

/// Classify a leaf view into its monomorphised load instruction.
fn load_instr(dst: Reg, leaf: u16, view: &View) -> Instr {
    if view.is_contiguous() {
        Instr::LoadContiguous { dst, leaf, base: view.base }
    } else if view.modulo.is_some() {
        Instr::LoadModulo { dst, leaf, view: *view }
    } else if view.col_stride == 0 && view.row_stride == 0 {
        Instr::LoadSplat { dst, leaf, idx: view.base }
    } else if view.col_stride == 0 {
        Instr::LoadBroadcast { dst, leaf, view: *view }
    } else {
        Instr::LoadStrided { dst, leaf, view: *view }
    }
}

/// Rank-1-update operand match on leaf-indexed trees (the tape analogue
/// of [`axpy_operands`]; the conditions are kept identical so both
/// executors special-case exactly the same trees).
fn axpy_leaves(p: &KTree, q: &KTree) -> Option<(u16, View, u16, View)> {
    let classify = |t: &KTree| match t {
        KTree::Leaf { leaf, view } => Some((*leaf, *view)),
        _ => None,
    };
    let (pl, pv) = classify(p)?;
    let (ql, qv) = classify(q)?;
    let is_bcast = |v: &View| v.col_stride == 0 && v.modulo.is_none();
    let is_row = |v: &View| v.col_stride == 1;
    if is_bcast(&pv) && is_row(&qv) {
        Some((pl, pv, ql, qv))
    } else if is_bcast(&qv) && is_row(&pv) {
        Some((ql, qv, pl, pv))
    } else {
        None
    }
}

/// Post-pass peepholes: merge `dst *= m; dst += c` (and the `-= c`
/// form) into one [`Instr::ScaleAddConst`] pass. The arithmetic is the
/// same two rounded operations, just one block traversal.
fn peephole(instrs: Vec<Instr>) -> Vec<Instr> {
    let mut out: Vec<Instr> = Vec::with_capacity(instrs.len());
    for ins in instrs {
        let last = out.last().copied();
        match (last, ins) {
            (
                Some(Instr::BinConst { op: BinOp::Mul, dst: d1, val: mul }),
                Instr::BinConst { op: op2, dst: d2, val: c },
            ) if d1 == d2 && matches!(op2, BinOp::Add | BinOp::Sub) => {
                let add = if op2 == BinOp::Sub { -c } else { c };
                out.pop();
                out.push(Instr::ScaleAddConst { dst: d2, mul, add });
            }
            (_, ins) => out.push(ins),
        }
    }
    out
}

/// A compiled fused kernel with its leaf buffers bound: the engine-side
/// tape (the serving layer binds leaves per request instead, through
/// [`TapeProgram::run_range_raw`]).
pub struct Tape {
    prog: TapeProgram,
    /// Keeps the leaf buffers alive; `raw` below points into them.
    _leaves: Vec<Arc<Vec<f64>>>,
    raw: Vec<LeafBind>,
}

// SAFETY: the raw bindings point into the heap buffers of the
// `Arc<Vec<f64>>`s held by `_leaves`, which live (and never move) as
// long as the Tape; all access through them is read-only.
unsafe impl Send for Tape {}
unsafe impl Sync for Tape {}

impl Tape {
    /// Compile an executable fused tree into a tape.
    pub fn compile(fx: &FExec) -> crate::Result<Tape> {
        let mut leaves: Vec<Arc<Vec<f64>>> = Vec::new();
        let kt = fexec_to_ktree(fx, &mut leaves)?;
        let prog = TapeProgram::compile(&kt)?;
        let raw = leaves.iter().map(|a| (a.as_ptr(), a.len())).collect();
        Ok(Tape { prog, _leaves: leaves, raw })
    }

    /// Lower an [`FTree`] and compile it — the engine's per-step entry
    /// (one compile, then every chunk of every block replays the tape).
    pub fn from_ftree(tree: &FTree) -> crate::Result<Tape> {
        Tape::compile(&lower(tree)?)
    }

    /// Execute over output indices `[start, start + out.len())`.
    pub fn run_range(&self, start: usize, out: &mut [f64], scratch: &mut Scratch) {
        // SAFETY: `raw` points into buffers owned by `self._leaves`,
        // alive for the duration of the call and disjoint from `out`
        // (the engine writes steps into freshly allocated buffers).
        unsafe { self.prog.run_range_raw(&self.raw, start, out, scratch) }
    }

    pub fn program(&self) -> &TapeProgram {
        &self.prog
    }
}

fn fexec_to_ktree(fx: &FExec, leaves: &mut Vec<Arc<Vec<f64>>>) -> crate::Result<KTree> {
    Ok(match fx {
        FExec::Leaf { data, view } => {
            if leaves.len() >= u16::MAX as usize {
                return Err(crate::Error::Invalid(
                    "fused tree has too many leaves for the tape VM".into(),
                ));
            }
            leaves.push(data.clone());
            KTree::Leaf { leaf: (leaves.len() - 1) as u16, view: *view }
        }
        FExec::Const(c) => KTree::Const(*c),
        FExec::Iota => KTree::Iota,
        FExec::Acc => KTree::Acc,
        FExec::Bin(op, a, b) => KTree::Bin(
            *op,
            Box::new(fexec_to_ktree(a, leaves)?),
            Box::new(fexec_to_ktree(b, leaves)?),
        ),
        FExec::Un(op, a) => KTree::Un(*op, Box::new(fexec_to_ktree(a, leaves)?)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaf(data: Vec<f64>, view: View) -> FExec {
        FExec::Leaf { data: Arc::new(data), view }
    }

    /// Evaluate through both executors and require bit-identical output.
    fn eval_both(fx: &FExec, start: usize, init: &[f64]) -> Vec<f64> {
        let mut tree_out = init.to_vec();
        eval_range(fx, start, &mut tree_out, &mut Scratch::default());
        let tape = Tape::compile(fx).unwrap();
        let mut tape_out = init.to_vec();
        tape.run_range(start, &mut tape_out, &mut Scratch::default());
        assert_eq!(tree_out.len(), tape_out.len());
        for (i, (a, b)) in tree_out.iter().zip(&tape_out).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "tape diverges from tree at {i}: {a:?} vs {b:?}"
            );
        }
        tree_out
    }

    #[test]
    fn eval_contiguous_add() {
        let a = leaf(vec![1.0, 2.0, 3.0, 4.0], View::identity(4));
        let b = leaf(vec![10.0, 20.0, 30.0, 40.0], View::identity(4));
        let fx = FExec::Bin(BinOp::Add, Box::new(a), Box::new(b));
        let out = eval_both(&fx, 0, &[0.0; 4]);
        assert_eq!(out, vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn eval_scalar_rhs() {
        let a = leaf(vec![1.0, 2.0], View::identity(2));
        let fx = FExec::Bin(BinOp::Mul, Box::new(a), Box::new(FExec::Const(3.0)));
        let out = eval_both(&fx, 0, &[0.0; 2]);
        assert_eq!(out, vec![3.0, 6.0]);
    }

    #[test]
    fn eval_strided_view() {
        // even elements of an 8-vector
        let v = View { base: 0, row_stride: 0, col_stride: 2, out_cols: 4, modulo: None };
        let fx = leaf((0..8).map(|x| x as f64).collect(), v);
        let out = eval_both(&fx, 0, &[0.0; 4]);
        assert_eq!(out, vec![0.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn eval_modulo_view() {
        let v = View { base: 0, row_stride: 4, col_stride: 1, out_cols: 4, modulo: Some(2) };
        let fx = leaf(vec![7.0, 9.0], v);
        let out = eval_both(&fx, 0, &[0.0; 8]);
        assert_eq!(out, vec![7.0, 9.0, 7.0, 9.0, 7.0, 9.0, 7.0, 9.0]);
    }

    #[test]
    fn eval_range_with_offset() {
        // Evaluating a sub-range must agree with evaluating the whole.
        let n = 100;
        let data: Vec<f64> = (0..n).map(|x| (x * x) as f64).collect();
        let fx = FExec::Un(
            UnOp::Sqrt,
            Box::new(leaf(data.clone(), View::identity(10))),
        );
        let init = vec![0.0; n];
        let full = eval_both(&fx, 0, &init);
        let part = eval_both(&fx, 25, &[0.0; 30]);
        assert_eq!(&full[25..55], part.as_slice());
    }

    #[test]
    fn eval_iota() {
        let fx = FExec::Iota;
        let out = eval_both(&fx, 10, &[0.0; 5]);
        assert_eq!(out, vec![10.0, 11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn acc_placement() {
        let ok = FExec::Bin(
            BinOp::Add,
            Box::new(FExec::Acc),
            Box::new(FExec::Const(1.0)),
        );
        assert!(ok.acc_placement_ok());
        let bad = FExec::Bin(
            BinOp::Add,
            Box::new(FExec::Const(1.0)),
            Box::new(FExec::Acc),
        );
        assert!(!bad.acc_placement_ok());
    }

    #[test]
    fn eval_accumulate_inplace() {
        // out starts as base; fx = Acc + leaf
        let addend = leaf(vec![1.0, 2.0, 3.0], View::identity(3));
        let fx = FExec::Bin(BinOp::Add, Box::new(FExec::Acc), Box::new(addend));
        let out = eval_both(&fx, 0, &[10.0, 20.0, 30.0]);
        assert_eq!(out, vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn lower_unmaterialised_leaf_is_error_not_panic() {
        use crate::coordinator::node::{Node, Op};
        use crate::coordinator::shape::{DType, Shape};
        // A pending node with no storage: lowering a plan that references
        // it must produce Error::Invalid (a serving worker must survive).
        let pending = Node::new(Op::Iota(4), Shape::D1(4), DType::F64);
        let tree = FTree::Leaf { node: pending, view: View::identity(4) };
        match lower(&tree) {
            Err(crate::Error::Invalid(msg)) => {
                assert!(msg.contains("not materialised"), "{msg}")
            }
            other => panic!("expected Error::Invalid, got {other:?}"),
        }
    }

    #[test]
    fn lower_rejects_acc_off_left_spine() {
        let bad = FTree::Bin(
            BinOp::Add,
            Box::new(FTree::Const(1.0)),
            Box::new(FTree::Acc),
        );
        assert!(lower(&bad).is_err());
    }

    #[test]
    fn tape_rejects_acc_off_left_spine() {
        let bad = FExec::Bin(
            BinOp::Add,
            Box::new(FExec::Const(1.0)),
            Box::new(FExec::Acc),
        );
        assert!(Tape::compile(&bad).is_err());
    }

    #[test]
    fn blocks_cross_boundaries() {
        let n = BLOCK * 3 + 17;
        let data: Vec<f64> = (0..n).map(|x| x as f64).collect();
        let fx = FExec::Bin(
            BinOp::Add,
            Box::new(leaf(data.clone(), View::identity(n))),
            Box::new(FExec::Const(0.5)),
        );
        let init = vec![0.0; n];
        let out = eval_both(&fx, 0, &init);
        for i in [0, 1, BLOCK - 1, BLOCK, 2 * BLOCK + 5, n - 1] {
            assert_eq!(out[i], i as f64 + 0.5);
        }
    }

    #[test]
    fn tape_left_deep_chain_reuses_one_register() {
        // ((((a + b) + c) + d) + e): every rhs leaf is released before
        // the next is lowered, so one scratch register suffices.
        let n = 8;
        let mk = |s: f64| leaf(vec![s; n], View::identity(n));
        let mut fx = mk(1.0);
        for k in 2..=5 {
            fx = FExec::Bin(BinOp::Add, Box::new(fx), Box::new(mk(k as f64)));
        }
        let tape = Tape::compile(&fx).unwrap();
        assert_eq!(tape.program().n_scratch_regs(), 1, "free-list must reuse registers");
        let out = eval_both(&fx, 0, &[0.0; 8]);
        assert_eq!(out[0], 15.0);
    }

    #[test]
    fn tape_emits_scale_add_const_peephole() {
        // a * 2 + 1  →  Load; ScaleAddConst
        let fx = FExec::Bin(
            BinOp::Add,
            Box::new(FExec::Bin(
                BinOp::Mul,
                Box::new(leaf(vec![1.0, 2.0, 3.0], View::identity(3))),
                Box::new(FExec::Const(2.0)),
            )),
            Box::new(FExec::Const(1.0)),
        );
        let tape = Tape::compile(&fx).unwrap();
        assert_eq!(tape.program().n_instrs(), 2, "{:?}", tape.program().instrs());
        assert!(matches!(tape.program().instrs()[1], Instr::ScaleAddConst { .. }));
        let out = eval_both(&fx, 0, &[0.0; 3]);
        assert_eq!(out, vec![3.0, 5.0, 7.0]);
    }

    #[test]
    fn tape_emits_mul_add_superinstruction() {
        // acc + x*y with non-axpy views → MulAdd, not Mul + Add.
        let n = 6;
        let x = leaf((0..n).map(|v| v as f64).collect(), View::identity(n));
        let y = leaf((0..n).map(|v| (v * 2) as f64).collect(), View::identity(n));
        let base = leaf(vec![1.0; n], View::identity(n));
        let fx = FExec::Bin(
            BinOp::Add,
            Box::new(base),
            Box::new(FExec::Bin(BinOp::Mul, Box::new(x), Box::new(y))),
        );
        let tape = Tape::compile(&fx).unwrap();
        assert!(
            tape.program()
                .instrs()
                .iter()
                .any(|i| matches!(i, Instr::MulAdd { .. })),
            "{:?}",
            tape.program().instrs()
        );
        let out = eval_both(&fx, 0, &[0.0; 6]);
        assert_eq!(out[3], 1.0 + 3.0 * 6.0);
    }

    #[test]
    fn tape_emits_axpy_superinstruction() {
        // colbcast(a) * row(b) under Add → the rank-1-update instruction.
        let oc = 8;
        let a = leaf(
            vec![2.0, 3.0],
            View { base: 0, row_stride: 1, col_stride: 0, out_cols: oc, modulo: None },
        );
        let b = leaf(
            (0..16).map(|v| v as f64).collect(),
            View { base: 0, row_stride: 8, col_stride: 1, out_cols: oc, modulo: None },
        );
        let fx = FExec::Bin(
            BinOp::Add,
            Box::new(FExec::Const(0.0)),
            Box::new(FExec::Bin(BinOp::Mul, Box::new(a), Box::new(b))),
        );
        let tape = Tape::compile(&fx).unwrap();
        assert!(
            tape.program().instrs().iter().any(|i| matches!(i, Instr::Axpy { .. })),
            "{:?}",
            tape.program().instrs()
        );
        let out = eval_both(&fx, 0, &[0.0; 16]);
        assert_eq!(out[1], 2.0); // row 0: 2.0 * b[1]
        assert_eq!(out[9], 3.0 * 9.0); // row 1: 3.0 * b[9]
    }

    #[test]
    fn tape_program_run_with_bound_leaves() {
        // The leaf-abstract entry: same program, rebound buffers.
        let kt = KTree::Bin(
            BinOp::Mul,
            Box::new(KTree::Leaf { leaf: 0, view: View::identity(4) }),
            Box::new(KTree::Splat { leaf: 1, idx: 0 }),
        );
        let prog = TapeProgram::compile(&kt).unwrap();
        assert_eq!(prog.n_leaves(), 2);
        let xs = [1.0, 2.0, 3.0, 4.0];
        let mut out = [0.0; 4];
        for s in [2.0, 10.0] {
            let scale = [s];
            prog.run_range(&[xs.as_slice(), scale.as_slice()], 0, &mut out, &mut Scratch::default());
            assert_eq!(out, [1.0 * s, 2.0 * s, 3.0 * s, 4.0 * s]);
        }
    }
}
