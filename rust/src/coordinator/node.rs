//! The expression IR: a reference-counted DAG of captured array operations.
//!
//! ArBB records the operations a "closure" performs on dense containers
//! into an intermediate representation which its JIT then optimises and
//! executes. We reproduce the same capture model with a lazily evaluated
//! DAG: every DSL operator allocates a [`Node`]; nothing executes until a
//! value is *needed* (a host read, a scalar extraction feeding control
//! flow, or an explicit sync), at which point the pending subgraph is
//! optimised, planned and run by the configured engine.

use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::map::MapFn;
use super::ops::{BinOp, RedOp, UnOp};
use super::shape::{DType, Shape};

/// Materialised container data. Buffers are `Arc`ed so execution plans
/// (which may cross threads) can hold references without copying.
#[derive(Debug, Clone)]
pub enum Data {
    F64(Arc<Vec<f64>>),
    I64(Arc<Vec<i64>>),
}

impl Data {
    pub fn len(&self) -> usize {
        match self {
            Data::F64(v) => v.len(),
            Data::I64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Data::F64(_) => DType::F64,
            Data::I64(_) => DType::I64,
        }
    }

    pub fn as_f64(&self) -> &Arc<Vec<f64>> {
        match self {
            Data::F64(v) => v,
            Data::I64(_) => panic!("expected f64 container, found i64"),
        }
    }

    pub fn as_i64(&self) -> &Arc<Vec<i64>> {
        match self {
            Data::I64(v) => v,
            Data::F64(_) => panic!("expected i64 container, found f64"),
        }
    }
}

/// Reference to an IR node.
pub type NodeRef = Rc<Node>;

/// Operations of the vector IR.
///
/// The "virtual" structural operators (`Row`, `Col`, `Section`,
/// `RepeatRow`, `RepeatCol`, `Repeat`, `Reshape`) are pure index
/// transforms — the fusion pass lowers them to [`super::shape::View`]s
/// instead of materialising temporaries, which is exactly the optimisation
/// the paper leans on in `arbb_mxm1`/`arbb_mxm2a` (`repeat_row` /
/// `repeat_col` feeding element-wise multiplies).
#[derive(Debug)]
pub enum Op {
    /// Bound/owned host data copied into "ArBB space" (the paper's `bind`).
    Source(Data),
    /// Scalar constant.
    ConstF64(f64),
    /// `iota(n)`: 0,1,2,…,n-1.
    Iota(usize),

    /// Element-wise binary op; operands have equal shape, or one is Scalar.
    Bin(BinOp, NodeRef, NodeRef),
    /// Element-wise unary op.
    Un(UnOp, NodeRef),

    /// Row `i` of a matrix (virtual).
    Row(NodeRef, usize),
    /// Column `j` of a matrix (virtual).
    Col(NodeRef, usize),
    /// `section(v, start, len, stride)` of a vector (virtual).
    Section { v: NodeRef, start: usize, len: usize, stride: usize },
    /// Matrix whose every row is `v` (virtual): `t(m,k) = v(k)`.
    RepeatRow { v: NodeRef, rows: usize },
    /// Matrix whose every column is `v` (virtual): `t(m,k) = v(m)`.
    RepeatCol { v: NodeRef, cols: usize },
    /// Cyclic tile of a vector, `times` repetitions (virtual).
    Repeat { v: NodeRef, times: usize },
    /// Reinterpret a container with a new shape of identical length
    /// (virtual).
    Reshape(NodeRef, Shape),

    /// Concatenate two vectors (materialising).
    Cat(NodeRef, NodeRef),
    /// Functional column replacement: copy of `m` with column `col` = `v`.
    /// Executes in place when `m`'s buffer is uniquely owned.
    ReplaceCol { m: NodeRef, col: usize, v: NodeRef },
    /// Functional row replacement.
    ReplaceRow { m: NodeRef, row: usize, v: NodeRef },
    /// Functional element store `m(i,j) = s` (the slow path `arbb_mxm0`
    /// exercises).
    SetElem { m: NodeRef, i: usize, j: usize, s: NodeRef },
    /// Gather: `out[k] = src[idx[k]]` with `idx` an i64 container.
    Gather { src: NodeRef, idx: NodeRef },
    /// Scatter: `out[idx[k]] = src[k]` into a zero-initialised vector of
    /// length `len` (duplicate indices: the last write wins).
    Scatter { src: NodeRef, idx: NodeRef, len: usize },

    /// Reduce along dimension 0 (within each row): `out[m] = red_k in(m,k)`.
    ReduceRows(RedOp, NodeRef),
    /// Reduce along dimension 1 (within each column): `out[k] = red_m in(m,k)`.
    ReduceCols(RedOp, NodeRef),
    /// Full reduction to a scalar.
    ReduceAll(RedOp, NodeRef),
    /// Segmented reduction with CSR row-pointer semantics:
    /// `out[r] = red over v[segp[r] .. segp[r+1]]` with `segp` an i64
    /// container of `nrows + 1` monotone offsets (empty segments emit the
    /// reduction identity). The spmv lowering of §3.2 is
    /// `segmented_reduce(Sum, vals * gather(x, indx), rowp)`.
    /// `runs_hint` asks the segmented executor to detect contiguous
    /// column runs in the fused gather's index table and stream them
    /// without the per-element gather (the paper's `arbb_spmv2`).
    SegmentedReduce { red: RedOp, v: NodeRef, segp: NodeRef, runs_hint: bool },

    /// ArBB `map()`: an elemental function invoked across all elements of
    /// the output, with random access to captured containers (the spmv
    /// kernels are built on this).
    Map(MapFn),
}

impl Op {
    /// Structural opcode id used for plan-cache signatures.
    pub fn opcode(&self) -> u32 {
        match self {
            Op::Source(_) => 0,
            Op::ConstF64(_) => 1,
            Op::Iota(_) => 2,
            Op::Bin(..) => 3,
            Op::Un(..) => 4,
            Op::Row(..) => 5,
            Op::Col(..) => 6,
            Op::Section { .. } => 7,
            Op::RepeatRow { .. } => 8,
            Op::RepeatCol { .. } => 9,
            Op::Repeat { .. } => 10,
            Op::Reshape(..) => 11,
            Op::Cat(..) => 12,
            Op::ReplaceCol { .. } => 13,
            Op::ReplaceRow { .. } => 14,
            Op::SetElem { .. } => 15,
            Op::Gather { .. } => 16,
            Op::ReduceRows(..) => 17,
            Op::ReduceCols(..) => 18,
            Op::ReduceAll(..) => 19,
            Op::Map(_) => 20,
            Op::SegmentedReduce { .. } => 21,
            Op::Scatter { .. } => 22,
        }
    }

    /// Children in evaluation order (cloned handles).
    pub fn children(&self) -> Vec<NodeRef> {
        match self {
            Op::Source(_) | Op::ConstF64(_) | Op::Iota(_) => vec![],
            Op::Bin(_, a, b)
            | Op::Cat(a, b)
            | Op::Gather { src: a, idx: b }
            | Op::Scatter { src: a, idx: b, .. }
            | Op::SegmentedReduce { v: a, segp: b, .. } => {
                vec![a.clone(), b.clone()]
            }
            Op::Un(_, a)
            | Op::Row(a, _)
            | Op::Col(a, _)
            | Op::Section { v: a, .. }
            | Op::RepeatRow { v: a, .. }
            | Op::RepeatCol { v: a, .. }
            | Op::Repeat { v: a, .. }
            | Op::Reshape(a, _)
            | Op::ReduceRows(_, a)
            | Op::ReduceCols(_, a)
            | Op::ReduceAll(_, a) => vec![a.clone()],
            Op::ReplaceCol { m, v, .. } | Op::ReplaceRow { m, v, .. } => {
                vec![m.clone(), v.clone()]
            }
            Op::SetElem { m, s, .. } => vec![m.clone(), s.clone()],
            Op::Map(f) => f.captures.clone(),
        }
    }

    /// Children moved out (used by the iterative `Drop`).
    fn take_children(self) -> Vec<NodeRef> {
        match self {
            Op::Source(_) | Op::ConstF64(_) | Op::Iota(_) => vec![],
            Op::Bin(_, a, b)
            | Op::Cat(a, b)
            | Op::Gather { src: a, idx: b }
            | Op::Scatter { src: a, idx: b, .. }
            | Op::SegmentedReduce { v: a, segp: b, .. } => vec![a, b],
            Op::Un(_, a)
            | Op::Row(a, _)
            | Op::Col(a, _)
            | Op::Section { v: a, .. }
            | Op::RepeatRow { v: a, .. }
            | Op::RepeatCol { v: a, .. }
            | Op::Repeat { v: a, .. }
            | Op::Reshape(a, _)
            | Op::ReduceRows(_, a)
            | Op::ReduceCols(_, a)
            | Op::ReduceAll(_, a) => vec![a],
            Op::ReplaceCol { m, v, .. } | Op::ReplaceRow { m, v, .. } => vec![m, v],
            Op::SetElem { m, s, .. } => vec![m, s],
            Op::Map(f) => f.captures,
        }
    }

    /// Whether this op is a pure index transform the fusion pass can
    /// absorb into a `View`.
    pub fn is_virtual_view(&self) -> bool {
        matches!(
            self,
            Op::Row(..)
                | Op::Col(..)
                | Op::Section { .. }
                | Op::RepeatRow { .. }
                | Op::RepeatCol { .. }
                | Op::Repeat { .. }
                | Op::Reshape(..)
        )
    }
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// A node of the captured expression DAG.
#[derive(Debug)]
pub struct Node {
    pub id: u64,
    /// The captured operation. Inside a `RefCell` so that, once the node
    /// is materialised, its children can be *released* (replaced by a
    /// `Source` of the result), freeing temporaries and breaking deep
    /// reference chains.
    pub op: RefCell<Op>,
    pub shape: Shape,
    pub dtype: DType,
    /// Materialised result cache (filled by the engine).
    pub storage: RefCell<Option<Data>>,
    /// Marker set when this node's buffer was donated to an in-place
    /// update (accumulation optimisation) — its storage is gone for good.
    pub donated: Cell<bool>,
}

impl Node {
    pub fn new(op: Op, shape: Shape, dtype: DType) -> NodeRef {
        Rc::new(Node {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            op: RefCell::new(op),
            shape,
            dtype,
            storage: RefCell::new(None),
            donated: Cell::new(false),
        })
    }

    /// A node that is already materialised (sources bound from host
    /// memory).
    pub fn new_source(shape: Shape, data: Data) -> NodeRef {
        let dtype = data.dtype();
        Rc::new(Node {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            op: RefCell::new(Op::Source(data.clone())),
            shape,
            dtype,
            storage: RefCell::new(Some(data)),
            donated: Cell::new(false),
        })
    }

    pub fn is_materialized(&self) -> bool {
        self.storage.borrow().is_some()
    }

    /// Clone of the materialised data (cheap: `Arc` bump).
    pub fn data(&self) -> Option<Data> {
        self.storage.borrow().clone()
    }

    /// Children handles.
    pub fn children(&self) -> Vec<NodeRef> {
        self.op.borrow().children()
    }

    pub fn opcode(&self) -> u32 {
        self.op.borrow().opcode()
    }

    /// Store the engine-produced result and drop the child references:
    /// a materialised node behaves exactly like a source from then on.
    pub fn materialize(&self, data: Data) {
        debug_assert_eq!(data.len(), self.shape.len(), "materialize length mismatch");
        *self.storage.borrow_mut() = Some(data.clone());
        // Release captured inputs: frees temporaries eagerly and keeps
        // Drop chains shallow.
        let old = std::mem::replace(&mut *self.op.borrow_mut(), Op::Source(data));
        // Drop the old op's children iteratively via the same machinery
        // as Node::drop.
        drop_children_iteratively(old.take_children());
    }
}

/// Iteratively tear down a forest of node references without recursing.
///
/// (`Node` has a custom `Drop`, so fields cannot be moved out of an
/// unwrapped value; instead, detach children through the `RefCell` while
/// we hold the last reference, leaving a trivial drop.)
fn drop_children_iteratively(mut stack: Vec<NodeRef>) {
    while let Some(c) = stack.pop() {
        if Rc::strong_count(&c) == 1 {
            let op = std::mem::replace(&mut *c.op.borrow_mut(), Op::ConstF64(0.0));
            stack.extend(op.take_children());
            // `c` drops here with no children attached.
        }
    }
}

impl Drop for Node {
    fn drop(&mut self) {
        // Replace our op with a leaf and tear the detached subtree down
        // iteratively — a deep chain (e.g. thousands of chained
        // accumulations that were never forced) must not overflow the
        // stack through recursive `Rc` drops.
        let op = std::mem::replace(&mut *self.op.borrow_mut(), Op::ConstF64(0.0));
        drop_children_iteratively(op.take_children());
    }
}

/// Structural signature of a pending subgraph, used as the plan-cache key.
///
/// Two DAGs receive the same signature iff they have the same topology,
/// opcodes, shapes and static parameters — buffer *contents* are excluded,
/// so the rank-1-update DAG built by every iteration of `arbb_mxm2a/b`'s
/// `_for` loop hits the cache after the first iteration (this models ArBB
/// capturing the loop body once and replaying the compiled closure).
pub fn structural_signature(root: &NodeRef) -> u64 {
    use std::collections::hash_map::DefaultHasher;
    use std::collections::HashMap;
    use std::hash::{Hash, Hasher};

    let mut local: HashMap<u64, u64> = HashMap::new();
    let mut hasher = DefaultHasher::new();
    let mut stack: Vec<(NodeRef, bool)> = vec![(root.clone(), false)];
    while let Some((n, expanded)) = stack.pop() {
        if !expanded && local.contains_key(&n.id) {
            continue;
        }
        if n.is_materialized() && n.id != root.id {
            let ln = local.len() as u64;
            local.insert(n.id, ln);
            (100u32, n.shape.len() as u64, n.dtype as u8 as u64).hash(&mut hasher);
            continue;
        }
        if !expanded {
            stack.push((n.clone(), true));
            for c in n.children() {
                if !local.contains_key(&c.id) {
                    stack.push((c, false));
                }
            }
        } else {
            if local.contains_key(&n.id) {
                continue;
            }
            let ln = local.len() as u64;
            local.insert(n.id, ln);
            n.opcode().hash(&mut hasher);
            n.shape.hash(&mut hasher);
            for c in n.children() {
                local.get(&c.id).copied().unwrap_or(u64::MAX).hash(&mut hasher);
            }
            match &*n.op.borrow() {
                Op::Bin(b, ..) => (*b as u8).hash(&mut hasher),
                Op::Un(u, ..) => (*u as u8).hash(&mut hasher),
                Op::ReduceRows(r, _) | Op::ReduceCols(r, _) | Op::ReduceAll(r, _) => {
                    (*r as u8).hash(&mut hasher)
                }
                Op::SegmentedReduce { red, runs_hint, .. } => {
                    (*red as u8, *runs_hint).hash(&mut hasher)
                }
                Op::Scatter { len, .. } => len.hash(&mut hasher),
                Op::Section { start, len, stride, .. } => (start, len, stride).hash(&mut hasher),
                Op::ConstF64(c) => c.to_bits().hash(&mut hasher),
                Op::Row(_, i) | Op::Col(_, i) => i.hash(&mut hasher),
                Op::SetElem { i, j, .. } => (i, j).hash(&mut hasher),
                Op::ReplaceCol { col, .. } => col.hash(&mut hasher),
                Op::ReplaceRow { row, .. } => row.hash(&mut hasher),
                _ => {}
            }
        }
    }
    hasher.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ops::BinOp;

    fn src(n: usize) -> NodeRef {
        Node::new_source(Shape::D1(n), Data::F64(Arc::new(vec![0.0; n])))
    }

    fn add(a: &NodeRef, b: &NodeRef) -> NodeRef {
        Node::new(Op::Bin(BinOp::Add, a.clone(), b.clone()), a.shape, DType::F64)
    }

    #[test]
    fn children_and_opcode() {
        let a = src(4);
        let b = src(4);
        let c = add(&a, &b);
        assert_eq!(c.children().len(), 2);
        assert_eq!(c.opcode(), 3);
        assert!(!c.is_materialized());
        assert!(a.is_materialized());
    }

    #[test]
    fn signature_is_structural() {
        let a = src(8);
        let b = src(8);
        let e1 = add(&a, &b);
        let e2 = add(&src(8), &src(8));
        assert_eq!(structural_signature(&e1), structural_signature(&e2));
        let e3 = Node::new(Op::Bin(BinOp::Mul, a, b), Shape::D1(8), DType::F64);
        assert_ne!(structural_signature(&e1), structural_signature(&e3));
        let e4 = add(&src(16), &src(16));
        assert_ne!(structural_signature(&e1), structural_signature(&e4));
    }

    #[test]
    fn materialize_releases_children() {
        let a = src(4);
        let b = src(4);
        let c = add(&a, &b);
        assert_eq!(c.children().len(), 2);
        c.materialize(Data::F64(Arc::new(vec![1.0; 4])));
        assert!(c.is_materialized());
        assert_eq!(c.children().len(), 0, "children released after materialize");
    }

    #[test]
    fn deep_chain_drop_does_not_overflow() {
        let a = src(8);
        let mut cur = add(&a, &a);
        for _ in 0..300_000 {
            cur = add(&cur, &a);
        }
        drop(cur); // must not blow the stack
    }

    #[test]
    fn virtual_views_flagged() {
        let a = src(16);
        let m = Node::new(
            Op::Reshape(a.clone(), Shape::D2 { rows: 4, cols: 4 }),
            Shape::D2 { rows: 4, cols: 4 },
            DType::F64,
        );
        assert!(m.op.borrow().is_virtual_view());
        let r = Node::new(Op::Row(m.clone(), 1), Shape::D1(4), DType::F64);
        assert!(r.op.borrow().is_virtual_view());
        let red = Node::new(Op::ReduceAll(RedOp::Sum, r), Shape::Scalar, DType::F64);
        assert!(!red.op.borrow().is_virtual_view());
    }
}
