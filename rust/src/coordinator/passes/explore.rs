//! Cost-based plan exploration — the capture-time auto-optimiser.
//!
//! ArBB's JIT picks lowerings (fusion, vectorisation strategy, blocking)
//! at capture time with a machine model baked into the compiler. This
//! pass reproduces that choice point explicitly: per **(kernel, shape,
//! backend)** it enumerates the alternative lowerings the engine
//! actually has —
//!
//!  * the three bit-identical segmented-spmv paths (blocked tape /
//!    fused gather-multiply-sum / contiguity runs),
//!  * dgemm row-panel granularity (`MC`),
//!  * the pooled-vs-serial chunking threshold,
//!  * batch-coalescing cutoffs for the serving scheduler,
//!
//! — scores them with the calibrated [`CostModel`] (per-opcode-class
//! ns/element, measured once per backend at startup), and memoizes the
//! winner in a [`Memo`]. The serving layer ([`crate::serve`]) probes the
//! frontrunners on live requests, feeds measured ns/element back into
//! the memo, and re-explores when measurement drifts ≥2× from the
//! estimate ([`drifted`]). The memo and the calibration constants
//! persist across restarts via [`crate::runtime::planstore`].

use std::collections::BTreeMap;

use crate::coordinator::engine::cost::CostModel;
use crate::coordinator::engine::tuning::SegPath;
use crate::coordinator::shape::{DType, Shape};
use crate::obs::profile::OpClass;

/// Measured-vs-estimated drift ratio that triggers re-exploration.
pub const DRIFT_RATIO: f64 = 2.0;

/// Assumed fork-join dispatch overhead (ns) when deriving the
/// pooled-vs-serial cutoff. A barrier on the warm shared pool costs on
/// the order of tens of microseconds end to end; the cutoff only needs
/// the right order of magnitude to keep tiny containers serial.
pub const FORK_JOIN_NS: f64 = 20_000.0;

/// Row-panel heights the dgemm exploration considers.
pub const DGEMM_MC_CANDIDATES: [usize; 4] = [32, 64, 128, 256];

/// Stable, human-readable signature of an argument list — part of the
/// memo key (shapes change the captured plan, so they key separately).
pub fn sig_string(args: &[(DType, Shape)]) -> String {
    let mut s = String::new();
    for (i, (dt, sh)) in args.iter().enumerate() {
        if i > 0 {
            s.push(';');
        }
        let d = match dt {
            DType::F64 => "f",
            DType::I64 => "i",
        };
        match sh {
            Shape::Scalar => s.push_str(&format!("{d}0")),
            Shape::D1(n) => s.push_str(&format!("{d}1:{n}")),
            Shape::D2 { rows, cols } => s.push_str(&format!("{d}2:{rows}x{cols}")),
        }
    }
    if s.is_empty() {
        s.push('-');
    }
    s
}

/// Memo key: one exploration decision per (kernel, backend, signature).
pub fn memo_key(kernel: &str, backend: &str, sig: &str) -> String {
    format!("{kernel}|{backend}|{sig}")
}

/// One memoized exploration decision.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoEntry {
    /// Winning lowering as a [`Tuning`](crate::coordinator::engine::tuning::Tuning)
    /// `k=v` string (`"-"` = the default lowering).
    pub variant: String,
    /// Cost-model estimate for the winner.
    pub est_ns_per_elem: f64,
    /// Probe/runtime measurement for the winner (EWMA once serving
    /// feedback arrives; equals the probe at exploration time).
    pub measured_ns_per_elem: f64,
    /// Plan generation this decision produced (bumped on every
    /// re-exploration hot swap, so stats can prove a swap happened).
    pub generation: u64,
    /// Set by the drift check; the next resolution for this key
    /// re-explores instead of trusting the memo.
    pub stale: bool,
}

/// The exploration memo: every decision taken so far, keyed by
/// [`memo_key`]. `BTreeMap` so persistence ([`crate::runtime::planstore`])
/// is deterministic.
#[derive(Debug, Default, Clone)]
pub struct Memo {
    pub entries: BTreeMap<String, MemoEntry>,
}

impl Memo {
    pub fn get(&self, key: &str) -> Option<&MemoEntry> {
        self.entries.get(key)
    }

    pub fn insert(&mut self, key: String, e: MemoEntry) {
        self.entries.insert(key, e);
    }

    /// Flag a key for re-exploration (the drift check's side of the
    /// feedback loop). Returns whether the key existed.
    pub fn mark_stale(&mut self, key: &str) -> bool {
        match self.entries.get_mut(key) {
            Some(e) => {
                e.stale = true;
                true
            }
            None => false,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Has runtime measurement drifted far enough from the estimate to
/// re-explore? Symmetric: a plan 2× slower *or* 2× faster than modelled
/// both mean the model's ranking for this key is unreliable.
pub fn drifted(est_ns_per_elem: f64, measured_ns_per_elem: f64) -> bool {
    if est_ns_per_elem <= 0.0 || measured_ns_per_elem <= 0.0 {
        return false;
    }
    let r = measured_ns_per_elem / est_ns_per_elem;
    !(1.0 / DRIFT_RATIO..DRIFT_RATIO).contains(&r)
}

/// Candidate forced paths for a segmented reduction whose
/// default-dispatch (best-available) path class is `best`. The default
/// dispatch prefers runs > fused > blocked; exploration checks whether
/// the cost model (and the probes) actually agree. `Auto` keeps the
/// default; forcing never *upgrades* (a path the tape cannot take is a
/// graceful no-op), so the candidate set shrinks with capability.
pub fn seg_candidates(best: OpClass) -> Vec<SegPath> {
    match best {
        OpClass::SegRuns => vec![SegPath::Auto, SegPath::Fused, SegPath::Blocked],
        OpClass::SegFused => vec![SegPath::Auto, SegPath::Blocked],
        _ => vec![SegPath::Auto],
    }
}

/// The opcode class a segmented reduction runs as when `forced` is
/// applied to a tape whose best-available path is `best`.
pub fn seg_path_class(best: OpClass, forced: SegPath) -> OpClass {
    match forced {
        SegPath::Auto => best,
        SegPath::Runs => {
            // Runs cannot be forced into existence; only kept.
            if best == OpClass::SegRuns {
                OpClass::SegRuns
            } else {
                best
            }
        }
        SegPath::Fused => {
            if best == OpClass::SegBlocked {
                OpClass::SegBlocked
            } else {
                OpClass::SegFused
            }
        }
        SegPath::Blocked => OpClass::SegBlocked,
    }
}

/// Explore dgemm row-panel height for an `m x k * k x n` product on
/// `workers` threads: returns `(MC, estimated seconds)`. Large panels
/// amortise packing but can leave workers idle (m=256 with MC=128 is
/// two panels on four workers); the calibrated model scores both
/// effects.
pub fn explore_dgemm(
    cost: &CostModel,
    m: usize,
    k: usize,
    n: usize,
    workers: usize,
) -> (usize, f64) {
    let mut best = (DGEMM_MC_CANDIDATES[0], f64::INFINITY);
    for &mc in &DGEMM_MC_CANDIDATES {
        let est = cost.dgemm_secs(m, k, n, mc, workers);
        if est < best.1 {
            best = (mc, est);
        }
    }
    best
}

/// Pooled-vs-serial threshold: containers below this element count run
/// serially (one chunk) because the estimated element-wise work is
/// cheaper than a fork-join dispatch.
pub fn pooled_cutoff(cost: &CostModel) -> usize {
    (FORK_JOIN_NS / cost.ns_for(OpClass::Bin)) as usize
}

/// Batch-coalescing cutoff for the serving scheduler: with an estimated
/// per-request cost and a coalescing latency budget, how many same-plan
/// requests one dispatch round should absorb. A zero budget means
/// "uncapped" (the scheduler's deadline slack still applies).
pub fn batch_cutoff(est_req_ns: f64, budget_ns: u64, max_batch: usize) -> usize {
    if budget_ns == 0 || est_req_ns <= 0.0 {
        return max_batch.max(1);
    }
    ((budget_ns as f64 / est_req_ns) as usize).clamp(1, max_batch.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::profile::N_CLASSES;

    #[test]
    fn sig_strings_are_stable_and_distinct() {
        let a = sig_string(&[(DType::F64, Shape::D1(512)), (DType::I64, Shape::D1(513))]);
        assert_eq!(a, "f1:512;i1:513");
        let b = sig_string(&[(DType::F64, Shape::D2 { rows: 4, cols: 8 })]);
        assert_eq!(b, "f2:4x8");
        assert_eq!(sig_string(&[]), "-");
        assert_eq!(sig_string(&[(DType::F64, Shape::Scalar)]), "f0");
        assert_ne!(a, b);
    }

    #[test]
    fn drift_is_symmetric_at_2x() {
        assert!(!drifted(10.0, 10.0));
        assert!(!drifted(10.0, 19.9));
        assert!(drifted(10.0, 20.0));
        assert!(drifted(10.0, 4.9));
        assert!(!drifted(10.0, 5.1));
        assert!(!drifted(0.0, 5.0), "uncalibrated estimates never drift");
    }

    #[test]
    fn seg_candidates_shrink_with_capability() {
        assert_eq!(seg_candidates(OpClass::SegRuns).len(), 3);
        assert_eq!(seg_candidates(OpClass::SegFused).len(), 2);
        assert_eq!(seg_candidates(OpClass::SegBlocked), vec![SegPath::Auto]);
    }

    #[test]
    fn forcing_never_upgrades_a_path() {
        assert_eq!(seg_path_class(OpClass::SegFused, SegPath::Runs), OpClass::SegFused);
        assert_eq!(seg_path_class(OpClass::SegBlocked, SegPath::Fused), OpClass::SegBlocked);
        assert_eq!(seg_path_class(OpClass::SegRuns, SegPath::Blocked), OpClass::SegBlocked);
        assert_eq!(seg_path_class(OpClass::SegRuns, SegPath::Auto), OpClass::SegRuns);
    }

    #[test]
    fn dgemm_exploration_fixes_worker_underutilisation() {
        let cost = CostModel::from_parts("scalar", [1.0; N_CLASSES]);
        let (mc, _) = explore_dgemm(&cost, 256, 256, 256, 4);
        assert!(mc <= 64, "4 workers need >= 4 panels of m=256, got MC={mc}");
    }

    #[test]
    fn batch_cutoff_scales_with_request_cost() {
        assert_eq!(batch_cutoff(1_000.0, 32_000, 64), 32);
        assert_eq!(batch_cutoff(100_000.0, 32_000, 64), 1);
        assert_eq!(batch_cutoff(1.0, 0, 64), 64, "zero budget = uncapped");
    }

    #[test]
    fn memo_stale_marking() {
        let mut m = Memo::default();
        let k = memo_key("spmv", "scalar", "f1:512");
        assert!(!m.mark_stale(&k));
        m.insert(
            k.clone(),
            MemoEntry {
                variant: "seg=runs".into(),
                est_ns_per_elem: 2.0,
                measured_ns_per_elem: 2.5,
                generation: 1,
                stale: false,
            },
        );
        assert!(m.mark_stale(&k));
        assert!(m.get(&k).unwrap().stale);
    }
}
