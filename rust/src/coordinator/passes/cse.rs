//! Structural common-subexpression elimination.
//!
//! Scans a pending region bottom-up, keying each node on
//! `(opcode, static params, child identities)`; structurally identical
//! pending nodes are rewritten to share a single representative, so the
//! planner emits one step instead of N.
//!
//! ArBB's JIT performs CSE on captured closures. In the paper's kernels
//! the effect is small (the hot loops are already hand-deduplicated), and
//! the pass costs a hash-map walk per dispatch — it is therefore *off* by
//! default and measured by the `ablations` bench, mirroring the paper's
//! observation that the runtime optimiser, not the programmer, should be
//! responsible for such rewrites (§4).

use std::collections::HashMap;

use crate::coordinator::node::{NodeRef, Op};
use crate::coordinator::passes::analyze::analyze;

/// Key describing a node structurally (children by identity).
#[derive(Hash, PartialEq, Eq)]
struct Key {
    opcode: u32,
    params: Vec<u64>,
    children: Vec<u64>,
}

fn key_of(n: &NodeRef, rep: &HashMap<u64, NodeRef>) -> Key {
    let op = n.op.borrow();
    let params: Vec<u64> = match &*op {
        Op::ConstF64(c) => vec![c.to_bits()],
        Op::Iota(n) => vec![*n as u64],
        Op::Bin(b, ..) => vec![*b as u64],
        Op::Un(u, ..) => vec![*u as u64],
        Op::Row(_, i) | Op::Col(_, i) => vec![*i as u64],
        Op::Section { start, len, stride, .. } => vec![*start as u64, *len as u64, *stride as u64],
        Op::RepeatRow { rows, .. } => vec![*rows as u64],
        Op::RepeatCol { cols, .. } => vec![*cols as u64],
        Op::Repeat { times, .. } => vec![*times as u64],
        Op::ReduceRows(r, _) | Op::ReduceCols(r, _) | Op::ReduceAll(r, _) => vec![*r as u64],
        Op::SegmentedReduce { red, runs_hint, .. } => vec![*red as u64, *runs_hint as u64],
        Op::Scatter { len, .. } => vec![*len as u64],
        Op::ReplaceCol { col, .. } => vec![*col as u64],
        Op::ReplaceRow { row, .. } => vec![*row as u64],
        Op::SetElem { i, j, .. } => vec![*i as u64, *j as u64],
        // Sources/maps are identified by node id (never merged).
        Op::Source(_) | Op::Map(_) => vec![n.id],
        _ => vec![],
    };
    let children = op
        .children()
        .iter()
        .map(|c| rep.get(&c.id).map(|r| r.id).unwrap_or(c.id))
        .collect();
    Key { opcode: op.opcode(), params, children }
}

/// Rewrite children of `n` to their representatives.
fn rewrite_children(n: &NodeRef, rep: &HashMap<u64, NodeRef>) {
    let mut op = n.op.borrow_mut();
    let replace = |c: &mut NodeRef| {
        if let Some(r) = rep.get(&c.id) {
            if r.id != c.id {
                *c = r.clone();
            }
        }
    };
    match &mut *op {
        Op::Bin(_, a, b)
        | Op::Cat(a, b)
        | Op::Gather { src: a, idx: b }
        | Op::Scatter { src: a, idx: b, .. }
        | Op::SegmentedReduce { v: a, segp: b, .. } => {
            replace(a);
            replace(b);
        }
        Op::Un(_, a)
        | Op::Row(a, _)
        | Op::Col(a, _)
        | Op::Section { v: a, .. }
        | Op::RepeatRow { v: a, .. }
        | Op::RepeatCol { v: a, .. }
        | Op::Repeat { v: a, .. }
        | Op::Reshape(a, _)
        | Op::ReduceRows(_, a)
        | Op::ReduceCols(_, a)
        | Op::ReduceAll(_, a) => replace(a),
        Op::ReplaceCol { m, v, .. } | Op::ReplaceRow { m, v, .. } => {
            replace(m);
            replace(v);
        }
        Op::SetElem { m, s, .. } => {
            replace(m);
            replace(s);
        }
        Op::Map(f) => {
            for c in &mut f.captures {
                replace(c);
            }
        }
        Op::Source(_) | Op::ConstF64(_) | Op::Iota(_) => {}
    }
}

/// Run CSE over the pending region rooted at `root`.
/// Returns the number of nodes eliminated.
pub fn cse(root: &NodeRef) -> usize {
    let an = analyze(root);
    let mut rep: HashMap<u64, NodeRef> = HashMap::new();
    let mut seen: HashMap<Key, NodeRef> = HashMap::new();
    let mut merged = 0;
    for n in &an.topo {
        rewrite_children(n, &rep);
        let k = key_of(n, &rep);
        match seen.get(&k) {
            Some(existing) if existing.id != n.id => {
                rep.insert(n.id, existing.clone());
                merged += 1;
            }
            Some(_) => {}
            None => {
                seen.insert(k, n.clone());
            }
        }
    }
    merged
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::node::{Data, Node};
    use crate::coordinator::ops::BinOp;
    use crate::coordinator::shape::{DType, Shape};
    use std::sync::Arc;

    fn src(n: usize) -> NodeRef {
        Node::new_source(Shape::D1(n), Data::F64(Arc::new(vec![1.0; n])))
    }

    fn add(a: &NodeRef, b: &NodeRef) -> NodeRef {
        Node::new(Op::Bin(BinOp::Add, a.clone(), b.clone()), a.shape, DType::F64)
    }

    #[test]
    fn merges_identical_subtrees() {
        let a = src(4);
        let b = src(4);
        let t1 = add(&a, &b);
        let t2 = add(&a, &b); // structurally identical
        let root = Node::new(Op::Bin(BinOp::Mul, t1, t2), Shape::D1(4), DType::F64);
        let merged = cse(&root);
        assert_eq!(merged, 1);
        // both children now point at the same node
        let ch = root.children();
        assert_eq!(ch[0].id, ch[1].id);
    }

    #[test]
    fn distinct_sources_not_merged() {
        let t1 = add(&src(4), &src(4));
        let t2 = add(&src(4), &src(4)); // different source nodes
        let root = Node::new(Op::Bin(BinOp::Mul, t1, t2), Shape::D1(4), DType::F64);
        assert_eq!(cse(&root), 0);
    }

    #[test]
    fn different_params_not_merged() {
        let a = src(4);
        let b = src(4);
        let t1 = add(&a, &b);
        let t2 = Node::new(Op::Bin(BinOp::Sub, a.clone(), b.clone()), Shape::D1(4), DType::F64);
        let root = Node::new(Op::Bin(BinOp::Mul, t1, t2), Shape::D1(4), DType::F64);
        assert_eq!(cse(&root), 0);
    }
}
