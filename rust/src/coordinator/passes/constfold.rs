//! Scalar constant folding.
//!
//! Applied at capture time: when a scalar operator's operands are both
//! compile-time constants, the DSL emits a folded constant node instead of
//! deferring the arithmetic to the engine. ArBB's JIT performs the same
//! folding on its intermediate representation; doing it at capture keeps
//! pending graphs (and per-`call()` dispatch cost) smaller, which matters
//! for the scalar-heavy CG driver loop (§3.4).

use crate::coordinator::node::{Node, NodeRef, Op};
use crate::coordinator::ops::{BinOp, UnOp};
use crate::coordinator::plan::const_value;
use crate::coordinator::shape::{DType, Shape};

/// Fold `l op r` for scalar nodes when both are constants.
/// Returns the folded node or `None` when not foldable.
pub fn fold_bin(op: BinOp, l: &NodeRef, r: &NodeRef) -> Option<NodeRef> {
    if !l.shape.is_scalar() || !r.shape.is_scalar() {
        return None;
    }
    let (lv, rv) = (const_value(l)?, const_value(r)?);
    Some(Node::new(Op::ConstF64(op.apply(lv, rv)), Shape::Scalar, DType::F64))
}

/// Fold `op x` for a scalar constant operand.
pub fn fold_un(op: UnOp, x: &NodeRef) -> Option<NodeRef> {
    if !x.shape.is_scalar() {
        return None;
    }
    let xv = const_value(x)?;
    Some(Node::new(Op::ConstF64(op.apply(xv)), Shape::Scalar, DType::F64))
}

/// Algebraic identities on vector ops with constant scalar operands:
/// `x * 1`, `x + 0`, `x - 0`, `x / 1` → `x`.
pub fn identity_elide(op: BinOp, l: &NodeRef, r: &NodeRef) -> Option<NodeRef> {
    let rv = const_value(r)?;
    let keep_left = match op {
        BinOp::Mul | BinOp::Div => rv == 1.0,
        BinOp::Add | BinOp::Sub => rv == 0.0,
        _ => false,
    };
    if keep_left && !l.shape.is_scalar() {
        Some(l.clone())
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::node::Data;
    use std::sync::Arc;

    fn c(v: f64) -> NodeRef {
        Node::new(Op::ConstF64(v), Shape::Scalar, DType::F64)
    }

    #[test]
    fn folds_scalar_chain() {
        let a = fold_bin(BinOp::Add, &c(2.0), &c(3.0)).unwrap();
        assert_eq!(const_value(&a), Some(5.0));
        let b = fold_bin(BinOp::Mul, &a, &c(4.0)).unwrap();
        assert_eq!(const_value(&b), Some(20.0));
        let s = fold_un(UnOp::Sqrt, &b).unwrap();
        assert!((const_value(&s).unwrap() - 20.0f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn does_not_fold_vectors() {
        let v = Node::new_source(Shape::D1(4), Data::F64(Arc::new(vec![1.0; 4])));
        assert!(fold_bin(BinOp::Add, &v, &c(1.0)).is_none());
    }

    #[test]
    fn identity_elision() {
        let v = Node::new_source(Shape::D1(4), Data::F64(Arc::new(vec![2.0; 4])));
        let kept = identity_elide(BinOp::Mul, &v, &c(1.0)).unwrap();
        assert_eq!(kept.id, v.id);
        assert!(identity_elide(BinOp::Mul, &v, &c(2.0)).is_none());
        assert!(identity_elide(BinOp::Add, &v, &c(0.0)).is_some());
        assert!(identity_elide(BinOp::Min, &v, &c(0.0)).is_none());
    }
}
