//! Optimisation passes of the capture→optimise→execute pipeline.
//!
//! * [`analyze`] — pending-region reachability, consumer counts, topo order
//!   (drives fusion and dead-code elimination: unreachable pending nodes
//!   are simply never planned, and dropped handles free their subgraphs).
//! * [`fusion`] — affine view composition for virtual structural
//!   operators, and the recompute-vs-materialise policy.
//! * [`constfold`] — scalar constant folding applied at capture time.
//! * [`cse`] — structural common-subexpression elimination over a pending
//!   region (optional; ablated in `benches/ablations.rs`).

pub mod analyze;
pub mod constfold;
pub mod cse;
pub mod fusion;
