//! Optimisation passes of the capture→optimise→execute pipeline.
//!
//! * [`analyze`] — pending-region reachability, consumer counts, topo order
//!   (drives fusion and dead-code elimination: unreachable pending nodes
//!   are simply never planned, and dropped handles free their subgraphs).
//! * [`fusion`] — affine view composition for virtual structural
//!   operators, and the recompute-vs-materialise policy.
//! * [`constfold`] — scalar constant folding applied at capture time.
//! * [`cse`] — structural common-subexpression elimination over a pending
//!   region (optional; ablated in `benches/ablations.rs`).
//! * [`explore`] — cost-based plan exploration: enumerates alternative
//!   lowerings per (kernel, shape, backend), scores them with the
//!   calibrated cost model and memoizes the winner (the serving layer
//!   probes, feeds runtime measurements back and persists the memo).

pub mod analyze;
pub mod constfold;
pub mod cse;
pub mod explore;
pub mod fusion;
