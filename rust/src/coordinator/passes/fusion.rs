//! View-composition rules for the fusion pass.
//!
//! ArBB's JIT avoids materialising the temporaries a naïvely-executed
//! data-parallel program would create: `repeat_row(b.col(i), n)` in
//! `arbb_mxm1` never becomes an n×n matrix — it is an index transform the
//! generated loop applies while streaming. We reproduce that with affine
//! [`View`]s: walking from a fused kernel's output space down through
//! virtual structural operators, each operator composes onto the view.
//! When a composition is not representable (rare corner cases, e.g. a
//! `repeat_col` under a non-identity view), the planner falls back to
//! materialising the operand — correctness never depends on fusability.

use crate::coordinator::node::Op;
use crate::coordinator::shape::{Shape, View};

/// Compose the view `v` (mapping the kernel's output flat index into the
/// *current* node's flat index space) through the virtual operator `op`,
/// yielding the view into the operator's input.
///
/// Returns `None` when the composition is not affine-representable; the
/// planner then materialises the input instead.
pub fn compose(op: &Op, v: &View) -> Option<View> {
    match op {
        // row i of an (rows × cols) matrix: input_flat = i*cols + cur_flat
        Op::Row(m, i) => {
            let cols = m.shape.cols();
            Some(offset(scale(v, 1), i * cols))
        }
        // col j: input_flat = cur_flat * cols + j
        Op::Col(m, j) => {
            let cols = m.shape.cols();
            Some(offset(scale(v, cols), *j))
        }
        // section(v, start, len, stride): input_flat = start + cur*stride
        Op::Section { start, stride, .. } => Some(offset(scale(v, *stride), *start)),
        // reshape: flat index unchanged
        Op::Reshape(..) => Some(*v),
        // repeat_row(x, rows): out(r,c) = x(c)  ⇒ input = cur_flat % len(x)
        Op::RepeatRow { v: x, .. } => {
            let len = x.shape.len();
            modulo(v, len)
        }
        // repeat(x, times): cyclic tile ⇒ input = cur_flat % len(x)
        Op::Repeat { v: x, .. } => {
            let len = x.shape.len();
            modulo(v, len)
        }
        // repeat_col(x, cols): out(r,c) = x(r) ⇒ input = cur_flat / cols.
        // Division is only representable when the incoming view is the
        // identity over this node's own (rows × cols) space: then the
        // output row index r is just idx / out_cols, i.e. a view with
        // row_stride 1 and col_stride 0.
        Op::RepeatCol { cols, .. } => {
            if v.base == 0
                && v.modulo.is_none()
                && v.col_stride == 1
                && v.row_stride == v.out_cols
                && v.out_cols == *cols
            {
                Some(View {
                    base: 0,
                    row_stride: 1,
                    col_stride: 0,
                    out_cols: v.out_cols,
                    modulo: None,
                })
            } else {
                None
            }
        }
        _ => None,
    }
}

/// Multiply all strides (and the modulo) of a view by `s`.
/// `(x mod m) * s == (x*s) mod (m*s)` for positive integers, so modulo
/// composes through scaling.
fn scale(v: &View, s: usize) -> View {
    View {
        base: v.base * s,
        row_stride: v.row_stride * s,
        col_stride: v.col_stride * s,
        out_cols: v.out_cols,
        modulo: v.modulo.map(|m| m * s),
    }
}

/// Add a constant offset to the final index. (`View::map` applies the
/// modulo to the linear part only and adds `base` afterwards, so a base
/// shift composes unconditionally.)
fn offset(v: View, off: usize) -> View {
    View { base: v.base + off, ..v }
}

/// Apply `% len` to the final index. Representable only when no base
/// offset or previous modulo interferes.
fn modulo(v: &View, len: usize) -> Option<View> {
    if v.base == 0 && v.modulo.is_none() {
        Some(View { modulo: Some(len), ..*v })
    } else if v.base == 0 && v.modulo == Some(len) {
        Some(*v)
    } else {
        None
    }
}

/// Size-aware fusability: an op with multiple pending consumers is still
/// worth recomputing inside each consumer when it is a zero-cost view;
/// element-wise work is materialised instead.
pub fn recompute_ok(op: &Op) -> bool {
    op.is_virtual_view() || matches!(op, Op::ConstF64(_) | Op::Iota(_))
}

/// Shape of the output index space a fused kernel evaluates under.
pub fn kernel_space(shape: &Shape) -> View {
    View::identity(shape.cols().max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::node::{Data, Node, NodeRef};
    use std::sync::Arc;

    fn mat(rows: usize, cols: usize) -> NodeRef {
        Node::new_source(
            Shape::D2 { rows, cols },
            Data::F64(Arc::new((0..rows * cols).map(|x| x as f64).collect())),
        )
    }

    fn vecn(n: usize) -> NodeRef {
        Node::new_source(Shape::D1(n), Data::F64(Arc::new((0..n).map(|x| x as f64).collect())))
    }

    /// mxm1's `repeat_row(b.col(i), n)` pattern: output space n×n,
    /// t(m,k) = b(k,i).
    #[test]
    fn repeat_row_of_col() {
        let n = 4;
        let b = mat(n, n);
        let col_i = Op::Col(b.clone(), 2);
        let rep = Op::RepeatRow { v: vecn(n), rows: n };

        let out = View::identity(n); // output space n×n
        let v1 = compose(&rep, &out).expect("repeat_row composes under identity");
        let v2 = compose(&col_i, &v1).expect("col composes");
        // t(m,k) = b[k][2] → flat = k*n + 2
        for m in 0..n {
            for k in 0..n {
                assert_eq!(v2.map(m * n + k), k * n + 2, "(m={m},k={k})");
            }
        }
    }

    /// mxm2a's `repeat_col(a.col(i), n)` pattern: t(m,k) = a(m,i).
    #[test]
    fn repeat_col_of_col() {
        let n = 4;
        let a = mat(n, n);
        let col_i = Op::Col(a.clone(), 1);
        let rep = Op::RepeatCol { v: vecn(n), cols: n };

        let out = View::identity(n);
        let v1 = compose(&rep, &out).expect("repeat_col composes under identity");
        let v2 = compose(&col_i, &v1).expect("col composes");
        for m in 0..n {
            for k in 0..n {
                assert_eq!(v2.map(m * n + k), m * n + 1, "(m={m},k={k})");
            }
        }
    }

    /// mxm2b also uses `repeat_row(b.row(k), n)`: t(m,j) = b(k,j).
    #[test]
    fn repeat_row_of_row() {
        let n = 4;
        let b = mat(n, n);
        let row_k = Op::Row(b.clone(), 3);
        let rep = Op::RepeatRow { v: vecn(n), rows: n };
        let out = View::identity(n);
        let v1 = compose(&rep, &out).unwrap();
        let v2 = compose(&row_k, &v1).unwrap();
        for m in 0..n {
            for j in 0..n {
                assert_eq!(v2.map(m * n + j), 3 * n + j);
            }
        }
    }

    /// FFT's `repeat(section(twiddles, 0, m), i)` pattern.
    #[test]
    fn repeat_of_section() {
        let tw = vecn(8);
        let m = 4;
        let sec = Op::Section { v: tw.clone(), start: 0, len: m, stride: 1 };
        let rep = Op::Repeat { v: vecn(m), times: 2 };
        let out = View::identity(8); // output length 8 vector
        let v1 = compose(&rep, &out).unwrap();
        let v2 = compose(&sec, &v1).unwrap();
        let got: Vec<usize> = (0..8).map(|i| v2.map(i)).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    /// FFT's strided even/odd sections.
    #[test]
    fn strided_section() {
        let data = vecn(8);
        let even = Op::Section { v: data.clone(), start: 0, len: 4, stride: 2 };
        let odd = Op::Section { v: data.clone(), start: 1, len: 4, stride: 2 };
        let out = View::identity(4);
        let ve = compose(&even, &out).unwrap();
        let vo = compose(&odd, &out).unwrap();
        assert_eq!((0..4).map(|i| ve.map(i)).collect::<Vec<_>>(), vec![0, 2, 4, 6]);
        assert_eq!((0..4).map(|i| vo.map(i)).collect::<Vec<_>>(), vec![1, 3, 5, 7]);
    }

    /// repeat_col under a non-identity view must refuse (fallback path).
    #[test]
    fn repeat_col_refuses_non_identity() {
        let n = 4;
        let rep = Op::RepeatCol { v: vecn(n), cols: n };
        let shifted = View { base: 5, ..View::identity(n) };
        assert!(compose(&rep, &shifted).is_none());
    }

    /// modulo after an offset must refuse.
    #[test]
    fn modulo_after_offset_refuses() {
        let rep = Op::RepeatRow { v: vecn(4), rows: 4 };
        let shifted = View { base: 2, ..View::identity(4) };
        assert!(compose(&rep, &shifted).is_none());
    }

    #[test]
    fn section_of_section_composes() {
        let data = vecn(16);
        let s1 = Op::Section { v: data.clone(), start: 2, len: 8, stride: 1 };
        // section(s1, 1, 4, 2): indices 1,3,5,7 of s1 = 3,5,7,9 of data
        let s2 = Op::Section { v: vecn(8), start: 1, len: 4, stride: 2 };
        let out = View::identity(4);
        let v2 = compose(&s2, &out).unwrap();
        let v1 = compose(&s1, &v2).unwrap();
        assert_eq!((0..4).map(|i| v1.map(i)).collect::<Vec<_>>(), vec![3, 5, 7, 9]);
    }
}
