//! Pending-subgraph analysis: reachability, consumer counts, topological
//! order. This is the front half of the capture→optimise→execute pipeline:
//! when a value is forced, we walk the pending (un-materialised) region of
//! the DAG rooted at it and gather the facts the fusion pass and planner
//! need.

use std::collections::HashMap;
use std::rc::Rc;

use crate::coordinator::node::NodeRef;

/// Analysis result over the pending subgraph of one `force()` call.
#[derive(Debug, Default)]
pub struct Analysis {
    /// Internal consumer count: number of pending parents referencing the
    /// node (edges inside the pending region).
    pub consumers: HashMap<u64, usize>,
    /// Pending nodes in topological (children-first) order.
    pub topo: Vec<NodeRef>,
}

impl Analysis {
    /// Number of pending consumers of `n`.
    pub fn consumer_count(&self, id: u64) -> usize {
        self.consumers.get(&id).copied().unwrap_or(0)
    }

    /// Conservative estimate of *external* references to a node: handles
    /// held by user code (or other pending DAGs from previous captures).
    ///
    /// `Rc::strong_count` counts every clone: one per parent op that holds
    /// the child plus one per user-facing container handle. Subtracting
    /// the internal edge count leaves the external references. A node with
    /// external references must be materialised (its value may be demanded
    /// again later); a node without them is a pure temporary that fusion
    /// may absorb.
    pub fn external_refs(&self, n: &NodeRef) -> usize {
        let internal = self.consumer_count(n.id);
        Rc::strong_count(n).saturating_sub(internal)
    }

    /// True when `n` is only consumed once inside this pending region —
    /// i.e. a fusable temporary.
    ///
    /// User handles deliberately do *not* block fusion: the paper's
    /// listings bind helper containers (`t`, `d` in `arbb_mxm1`) purely
    /// for readability, and ArBB's capture semantics fuse them anyway. If
    /// such a handle is read later, the value is simply recomputed
    /// (lazy-functional semantics); buffer *donation* is the only
    /// transformation that needs true uniqueness, and it checks
    /// `Rc::strong_count` separately.
    pub fn is_private_temp(&self, n: &NodeRef) -> bool {
        self.consumer_count(n.id) == 1
    }
}

/// Analyse the pending region reachable from `root`.
///
/// Materialised nodes terminate the walk (they are inputs, not work).
pub fn analyze(root: &NodeRef) -> Analysis {
    let mut an = Analysis::default();
    if root.is_materialized() {
        return an;
    }
    // Iterative DFS with explicit post-order. Chains can be very deep
    // (`arbb_mxm1` builds an n-deep replace_col chain before the first
    // read), so no recursion here.
    #[derive(Clone, Copy, PartialEq)]
    enum Mark {
        Visiting,
        Done,
    }
    let mut marks: HashMap<u64, Mark> = HashMap::new();
    let mut stack: Vec<(NodeRef, bool)> = vec![(root.clone(), false)];
    while let Some((n, expanded)) = stack.pop() {
        if expanded {
            marks.insert(n.id, Mark::Done);
            an.topo.push(n);
            continue;
        }
        match marks.get(&n.id) {
            Some(Mark::Done) => continue,
            Some(Mark::Visiting) => continue, // re-push of an in-flight node
            None => {}
        }
        marks.insert(n.id, Mark::Visiting);
        stack.push((n.clone(), true));
        for c in n.children() {
            if c.is_materialized() {
                continue;
            }
            *an.consumers.entry(c.id).or_insert(0) += 1;
            if !marks.contains_key(&c.id) {
                stack.push((c, false));
            }
        }
    }
    // Count edges into pending children from *materialised* parents too?
    // Not needed: materialised parents never re-execute.
    //
    // Edges from the forced root itself: the root has at least the caller's
    // handle; give it one consumer so external_refs math stays uniform.
    *an.consumers.entry(root.id).or_insert(0) += 0;
    an
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::node::{Data, Node, Op};
    use crate::coordinator::ops::BinOp;
    use crate::coordinator::shape::{DType, Shape};
    use std::sync::Arc;

    fn src(n: usize) -> NodeRef {
        Node::new_source(Shape::D1(n), Data::F64(Arc::new(vec![1.0; n])))
    }

    fn add(a: &NodeRef, b: &NodeRef) -> NodeRef {
        Node::new(Op::Bin(BinOp::Add, a.clone(), b.clone()), a.shape, DType::F64)
    }

    #[test]
    fn counts_shared_temporary() {
        let a = src(4);
        let t = add(&a, &a); // pending temp
        let u = add(&t, &t); // consumes t twice
        let an = analyze(&u);
        assert_eq!(an.consumer_count(t.id), 2);
        assert!(!an.is_private_temp(&t));
        // topo: t before u
        let pos_t = an.topo.iter().position(|n| n.id == t.id).unwrap();
        let pos_u = an.topo.iter().position(|n| n.id == u.id).unwrap();
        assert!(pos_t < pos_u);
    }

    #[test]
    fn private_temp_detected() {
        let a = src(4);
        let b = src(4);
        let t = add(&a, &b);
        let u = add(&t, &b);
        let an = analyze(&u);
        assert_eq!(an.consumer_count(t.id), 1);
        assert!(an.is_private_temp(&t));
        drop(u);
    }

    #[test]
    fn user_handle_does_not_block_fusion() {
        let a = src(4);
        let t = add(&a, &a);
        let u = add(&t, &a);
        let an = analyze(&u);
        // `t` is held by this test (a user handle) but consumed once in
        // the region: still a fusable temp (recompute-on-later-read).
        assert_eq!(an.consumer_count(t.id), 1);
        assert!(an.external_refs(&t) >= 2); // parent edge + our binding
        assert!(an.is_private_temp(&t));
    }

    #[test]
    fn deep_chain_no_stack_overflow() {
        let a = src(8);
        let mut cur = add(&a, &a);
        for _ in 0..200_000 {
            cur = add(&cur, &a);
        }
        let an = analyze(&cur);
        assert_eq!(an.topo.len(), 200_001);
        // Node::drop tears chains down iteratively.
    }
}
