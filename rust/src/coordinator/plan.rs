//! Lowering: pending expression DAG → execution plan.
//!
//! This is the optimiser half of the "JIT": given the pending subgraph
//! rooted at a forced value, decide which nodes materialise (steps) and
//! which fuse into their consumer's loop (element-wise chains and virtual
//! views), detect in-place accumulation opportunities, and emit a
//! topologically ordered list of [`Step`]s for the engine.
//!
//! The emitted [`FTree`]s are compiled once per step into flat
//! instruction tapes by [`super::engine::eval::Tape`] — the planner
//! decides *what* fuses, the tape compiler decides *how* the fused loop
//! runs (register allocation, monomorphised loads, superinstructions).
//!
//! The optimisations modelled after ArBB's JIT:
//!  * **element-wise fusion** — private temporaries never hit memory;
//!  * **view absorption** — `row/col/section/repeat_*` become index
//!    transforms ([`super::passes::fusion`]);
//!  * **reduction fusion** — a reduction evaluates its fused operand
//!    row-block-wise (the `add_reduce(a.row(i) * b.col(j))` pattern);
//!  * **in-place accumulation** — `c = c + x` donates `c`'s buffer when
//!    provably dead (the `c += outer-product` loop of `arbb_mxm2a/b`);
//!  * **in-place structural update** — `replace_col`/`set_elem` mutate
//!    instead of copy when the operand is uniquely owned.

use std::collections::HashSet;
use std::rc::Rc;

use super::node::{Node, NodeRef, Op};
use super::ops::{BinOp, RedOp, UnOp};
use super::passes::analyze::{analyze, Analysis};
use super::passes::fusion::{compose, kernel_space};
use super::shape::{DType, View};

/// Upper bound on the number of operators fused into a single kernel.
/// Long un-forced accumulation chains (building `c = c + x_k` for every
/// `k` before any read) are split into segments of this size, bounding
/// scratch usage while still amortising memory traffic.
pub const MAX_FUSE_OPS: usize = 96;

/// A fused element-wise expression tree evaluated block-wise.
#[derive(Debug)]
pub enum FTree {
    /// Materialised input read through an affine view.
    Leaf { node: NodeRef, view: View },
    /// Fused gather leaf: element `k` of the kernel's output space reads
    /// `src[idx[base + k]]`. Produced when a `gather` node is absorbed
    /// into its consumer's loop instead of materialising (the spmv
    /// lowering); `src` and `idx` are materialised by then.
    Gather { src: NodeRef, idx: NodeRef, base: usize },
    /// Scalar constant.
    Const(f64),
    /// Broadcast of a (materialised-by-then) scalar node.
    ScalarLeaf { node: NodeRef },
    /// Flat output index as a value (iota).
    Iota,
    /// The current value of the output buffer (in-place accumulation).
    Acc,
    Bin(BinOp, Box<FTree>, Box<FTree>),
    Un(UnOp, Box<FTree>),
}

impl FTree {
    /// FLOPs per produced element (for stats and the scaling simulator).
    pub fn flops_per_elem(&self) -> f64 {
        match self {
            FTree::Bin(op, a, b) => op.flops() + a.flops_per_elem() + b.flops_per_elem(),
            FTree::Un(op, a) => op.flops() + a.flops_per_elem(),
            _ => 0.0,
        }
    }

    /// Bytes of *input* traffic per produced element (8 per distinct leaf;
    /// broadcast leaves are counted once and amortise to ~0, but we keep
    /// the pessimistic estimate simple).
    pub fn bytes_per_elem(&self) -> f64 {
        match self {
            FTree::Leaf { view, .. } => {
                // Broadcast leaves (stride 0 in both dims) stay in register.
                if view.row_stride == 0 && view.col_stride == 0 {
                    0.0
                } else {
                    8.0
                }
            }
            // Fused gather: 8 bytes of index plus 8 of (randomly
            // addressed) data per element.
            FTree::Gather { .. } => 16.0,
            FTree::ScalarLeaf { .. } | FTree::Const(_) | FTree::Iota => 0.0,
            FTree::Acc => 8.0,
            FTree::Bin(_, a, b) => a.bytes_per_elem() + b.bytes_per_elem(),
            FTree::Un(_, a) => a.bytes_per_elem(),
        }
    }

    /// Operator count of the fused tree (fusion-depth statistics for
    /// tests, ablations and the tape compiler's sizing heuristics).
    pub fn count_ops(&self) -> usize {
        match self {
            FTree::Bin(_, a, b) => 1 + a.count_ops() + b.count_ops(),
            FTree::Un(_, a) => 1 + a.count_ops(),
            _ => 0,
        }
    }
}

/// One unit of engine work, materialising exactly one node.
#[derive(Debug)]
pub enum Step {
    /// Evaluate `tree` over the flat index space of `out`.
    Fused { out: NodeRef, tree: FTree },
    /// In-place: `out` takes `base`'s donated buffer (already holding the
    /// starting values); `tree` contains an [`FTree::Acc`] leaf.
    Accumulate { out: NodeRef, base: NodeRef, tree: FTree },
    /// Row-wise reduction of a fused operand: `out[m] = red_k tree(m,k)`.
    ReduceRows { out: NodeRef, red: RedOp, tree: FTree, rows: usize, cols: usize },
    /// Column-wise reduction: `out[k] = red_m tree(m,k)`.
    ReduceCols { out: NodeRef, red: RedOp, tree: FTree, rows: usize, cols: usize },
    /// Full reduction to a scalar.
    ReduceAll { out: NodeRef, red: RedOp, tree: FTree, len: usize },
    /// Segmented reduction over CSR row-pointer segments:
    /// `out[r] = red over tree(segp[r] .. segp[r+1])` with `tree` fused
    /// over the flat nnz index space. Executed by the segmented tape
    /// ([`super::engine::eval::SegTape`]) in parallel over nnz-balanced
    /// row panels; `runs_hint` enables contiguity-run detection.
    SegmentedReduce {
        out: NodeRef,
        red: RedOp,
        tree: FTree,
        segp: NodeRef,
        rows: usize,
        nnz: usize,
        runs_hint: bool,
    },
    /// Vector concatenation; both halves are fused trees.
    Cat { out: NodeRef, a: FTree, la: usize, b: FTree, lb: usize },
    /// Column replacement (in place when donatable).
    ReplaceCol { out: NodeRef, m: NodeRef, col: usize, vtree: FTree },
    /// Row replacement.
    ReplaceRow { out: NodeRef, m: NodeRef, row: usize, vtree: FTree },
    /// Single element store.
    SetElem { out: NodeRef, m: NodeRef, i: usize, j: usize, s: NodeRef },
    /// Gather through an i64 index container.
    Gather { out: NodeRef, src: NodeRef, idx: NodeRef },
    /// Scatter through an i64 index container (zero-filled output).
    Scatter { out: NodeRef, src: NodeRef, idx: NodeRef },
    /// ArBB `map()` over the output elements.
    Map { out: NodeRef },
}

impl Step {
    pub fn out(&self) -> &NodeRef {
        match self {
            Step::Fused { out, .. }
            | Step::Accumulate { out, .. }
            | Step::ReduceRows { out, .. }
            | Step::ReduceCols { out, .. }
            | Step::ReduceAll { out, .. }
            | Step::SegmentedReduce { out, .. }
            | Step::Cat { out, .. }
            | Step::ReplaceCol { out, .. }
            | Step::ReplaceRow { out, .. }
            | Step::SetElem { out, .. }
            | Step::Gather { out, .. }
            | Step::Scatter { out, .. }
            | Step::Map { out } => out,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Step::Fused { .. } => "fused",
            Step::Accumulate { .. } => "accumulate",
            Step::ReduceRows { .. } => "reduce_rows",
            Step::ReduceCols { .. } => "reduce_cols",
            Step::ReduceAll { .. } => "reduce_all",
            Step::SegmentedReduce { .. } => "segmented_reduce",
            Step::Cat { .. } => "cat",
            Step::ReplaceCol { .. } => "replace_col",
            Step::ReplaceRow { .. } => "replace_row",
            Step::SetElem { .. } => "set_elem",
            Step::Gather { .. } => "gather",
            Step::Scatter { .. } => "scatter",
            Step::Map { .. } => "map",
        }
    }
}

/// An executable plan: steps in dependency order.
#[derive(Debug, Default)]
pub struct Plan {
    pub steps: Vec<Step>,
}

/// Planner options (a subset of [`super::Options`] relevant to lowering).
#[derive(Debug, Clone, Copy)]
pub struct PlanOptions {
    /// Element-wise fusion on/off (the paper's headline optimisation;
    /// ablated by `benches/ablations.rs`).
    pub fusion: bool,
    /// Allow in-place buffer donation.
    pub in_place: bool,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions { fusion: true, in_place: true }
    }
}

/// Plan the pending subgraph rooted at `root`.
pub fn plan(root: &NodeRef, opts: PlanOptions) -> Plan {
    let mut planner = Planner {
        an: analyze(root),
        opts,
        plan: Plan::default(),
        planned: HashSet::new(),
    };
    if root.is_materialized() {
        return planner.plan;
    }
    planner.run(root);
    planner.plan
}

struct Planner {
    an: Analysis,
    opts: PlanOptions,
    plan: Plan,
    planned: HashSet<u64>,
}

impl Planner {
    fn run(&mut self, root: &NodeRef) {
        // Pass 1: decide the initial set of materialisation roots.
        // Alongside the structural rules, track the *fused-region size*
        // bottom-up and cut at MAX_FUSE_OPS: an un-forced 50k-deep
        // accumulation chain must become ~500 bounded steps, not one
        // planner recursion 50k frames deep.
        let mut roots: HashSet<u64> = HashSet::new();
        roots.insert(root.id);
        let topo = std::mem::take(&mut self.an.topo);
        let mut fdepth: std::collections::HashMap<u64, usize> =
            std::collections::HashMap::new();
        for n in &topo {
            if self.must_materialize(n) {
                roots.insert(n.id);
                fdepth.insert(n.id, 0);
                continue;
            }
            let d = {
                let op = n.op.borrow();
                let child_d = |c: &NodeRef| {
                    if roots.contains(&c.id) {
                        0
                    } else {
                        fdepth.get(&c.id).copied().unwrap_or(0)
                    }
                };
                match &*op {
                    Op::Bin(_, a, b) => 1 + child_d(a) + child_d(b),
                    Op::Un(_, a) => 1 + child_d(a),
                    o if o.is_virtual_view() => {
                        o.children().first().map(&child_d).unwrap_or(0)
                    }
                    _ => 0,
                }
            };
            if d > MAX_FUSE_OPS {
                roots.insert(n.id);
                fdepth.insert(n.id, 0);
            } else {
                fdepth.insert(n.id, d);
            }
        }
        // Pass 2: emit steps in topo order. Tree building may promote
        // additional nodes (failed view compositions, fusion-cap cuts);
        // `emit` recurses on those first — promotion chains are shallow.
        for n in &topo {
            if roots.contains(&n.id) {
                self.emit(n);
            }
        }
        self.an.topo = topo;
        // The forced root is always last; make sure it was emitted even if
        // topo missed it (single-node graphs).
        if !self.planned.contains(&root.id) {
            self.emit(root);
        }
    }

    /// Ops that can never be absorbed into a consumer's loop.
    fn must_materialize(&self, n: &NodeRef) -> bool {
        if n.is_materialized() {
            return false;
        }
        let op = n.op.borrow();
        match &*op {
            Op::Bin(..) | Op::Un(..) => {
                // Element-wise: materialise when shared or when fusion is
                // disabled (the "every operator writes a temporary" mode).
                !self.opts.fusion || !self.an.is_private_temp(n)
            }
            Op::Gather { .. } => {
                // A private gather is absorbed into its consumer's fused
                // loop (the tape VM's gather loader); `build_tree` falls
                // back to a materialising Gather step when the consuming
                // view turns out not to compose.
                !self.opts.fusion || !self.an.is_private_temp(n)
            }
            op if op.is_virtual_view() => false, // views recompute for free
            Op::Source(_) | Op::ConstF64(_) => false,
            Op::Iota(_) => false,
            _ => true, // reductions, cat, replace, set, gather, map
        }
    }

    /// Emit the step materialising `n` (dependencies first).
    fn emit(&mut self, n: &NodeRef) {
        if n.is_materialized() || self.planned.contains(&n.id) {
            return;
        }
        self.planned.insert(n.id);
        let op = n.op.borrow();
        let step = match &*op {
            Op::Source(_) => None,
            Op::ConstF64(c) => {
                // Forcing a constant scalar: materialise directly.
                let c = *c;
                drop(op);
                n.materialize(super::node::Data::F64(std::sync::Arc::new(vec![c])));
                self.planned.remove(&n.id);
                return;
            }
            Op::Iota(_) => Some(Step::Fused { out: n.clone(), tree: FTree::Iota }),
            Op::Bin(..) | Op::Un(..) => {
                drop(op);
                return self.emit_elementwise(n);
            }
            Op::ReduceRows(red, input) => {
                let (red, input) = (*red, input.clone());
                drop(op);
                let (rows, cols) = (input.shape.rows(), input.shape.cols());
                let tree = self.build_tree(&input, kernel_space(&input.shape), &mut 0, false);
                Some(Step::ReduceRows { out: n.clone(), red, tree, rows, cols })
            }
            Op::ReduceCols(red, input) => {
                let (red, input) = (*red, input.clone());
                drop(op);
                let (rows, cols) = (input.shape.rows(), input.shape.cols());
                let tree = self.build_tree(&input, kernel_space(&input.shape), &mut 0, false);
                Some(Step::ReduceCols { out: n.clone(), red, tree, rows, cols })
            }
            Op::ReduceAll(red, input) => {
                let (red, input) = (*red, input.clone());
                drop(op);
                let len = input.shape.len();
                let tree = self.build_tree(&input, kernel_space(&input.shape), &mut 0, false);
                Some(Step::ReduceAll { out: n.clone(), red, tree, len })
            }
            Op::SegmentedReduce { red, v, segp, runs_hint } => {
                let (red, v, segp, runs_hint) = (*red, v.clone(), segp.clone(), *runs_hint);
                drop(op);
                self.ensure(&segp);
                let rows = n.shape.len();
                let nnz = v.shape.len();
                // The operand fuses over the flat nnz index space —
                // element-wise chains and gather leaves are absorbed so
                // the segmented tape streams them in one pass.
                let tree = self.build_tree(&v, kernel_space(&v.shape), &mut 0, false);
                Some(Step::SegmentedReduce {
                    out: n.clone(),
                    red,
                    tree,
                    segp,
                    rows,
                    nnz,
                    runs_hint,
                })
            }
            Op::Cat(a, b) => {
                let (a, b) = (a.clone(), b.clone());
                drop(op);
                let (la, lb) = (a.shape.len(), b.shape.len());
                let ta = self.build_tree(&a, kernel_space(&a.shape), &mut 0, false);
                let tb = self.build_tree(&b, kernel_space(&b.shape), &mut 0, false);
                Some(Step::Cat { out: n.clone(), a: ta, la, b: tb, lb })
            }
            Op::ReplaceCol { m, col, v } => {
                let (m, col, v) = (m.clone(), *col, v.clone());
                drop(op);
                self.ensure(&m);
                let vtree = self.build_tree(&v, kernel_space(&v.shape), &mut 0, false);
                Some(Step::ReplaceCol { out: n.clone(), m, col, vtree })
            }
            Op::ReplaceRow { m, row, v } => {
                let (m, row, v) = (m.clone(), *row, v.clone());
                drop(op);
                self.ensure(&m);
                let vtree = self.build_tree(&v, kernel_space(&v.shape), &mut 0, false);
                Some(Step::ReplaceRow { out: n.clone(), m, row, vtree })
            }
            Op::SetElem { m, i, j, s } => {
                let (m, i, j, s) = (m.clone(), *i, *j, s.clone());
                drop(op);
                self.ensure(&m);
                self.ensure(&s);
                Some(Step::SetElem { out: n.clone(), m, i, j, s })
            }
            Op::Gather { src, idx } => {
                let (src, idx) = (src.clone(), idx.clone());
                drop(op);
                self.ensure(&src);
                self.ensure(&idx);
                Some(Step::Gather { out: n.clone(), src, idx })
            }
            Op::Scatter { src, idx, .. } => {
                let (src, idx) = (src.clone(), idx.clone());
                drop(op);
                self.ensure(&src);
                self.ensure(&idx);
                Some(Step::Scatter { out: n.clone(), src, idx })
            }
            Op::Map(f) => {
                let captures = f.captures.clone();
                drop(op);
                for c in &captures {
                    self.ensure(c);
                }
                Some(Step::Map { out: n.clone() })
            }
            // Remaining ops are the virtual views (Row/Col/Section/
            // Repeat*/Reshape), promoted to materialisation: copy the
            // child through the composed view. From an identity space
            // every view operator composes (refusals only arise under
            // already-transformed views), so `compose` cannot fail here.
            other => {
                debug_assert!(other.is_virtual_view(), "unhandled op in planner");
                let space = kernel_space(&n.shape);
                let composed =
                    compose(&op, &space).expect("virtual view must compose from identity space");
                let child = op.children().pop().expect("view has one child");
                drop(op);
                let tree = self.build_tree(&child, composed, &mut 0, false);
                return self.push(Step::Fused { out: n.clone(), tree });
            }
        };
        if let Some(s) = step {
            self.plan.steps.push(s);
        } else {
            // Source/Const: nothing to do (treated as materialised).
            self.planned.remove(&n.id);
        }
    }

    fn push(&mut self, s: Step) {
        self.plan.steps.push(s);
    }

    /// Make sure `n` is materialised before the step being built.
    fn ensure(&mut self, n: &NodeRef) {
        if !n.is_materialized() && !self.planned.contains(&n.id) {
            self.emit(n);
        }
    }

    /// Element-wise root: try the in-place accumulation pattern first.
    fn emit_elementwise(&mut self, n: &NodeRef) {
        if self.opts.in_place {
            if let Some(step) = self.try_accumulate(n) {
                return self.push(step);
            }
        }
        let tree = self.build_tree_children(n, kernel_space(&n.shape), &mut 0);
        self.push(Step::Fused { out: n.clone(), tree });
    }

    /// Detect `c = ((c ⊕ x₁) ⊕ x₂) …` with a dead, uniquely-owned `c`:
    /// replace the leftmost leaf by `Acc` and donate the buffer.
    fn try_accumulate(&mut self, n: &NodeRef) -> Option<Step> {
        // Walk the left spine of private Add/Sub temps.
        let mut spine: Vec<NodeRef> = vec![n.clone()];
        loop {
            let cur = spine.last().unwrap().clone();
            let op = cur.op.borrow();
            match &*op {
                Op::Bin(BinOp::Add, l, _) | Op::Bin(BinOp::Sub, l, _) => {
                    let l = l.clone();
                    drop(op);
                    if l.is_materialized() {
                        // Candidate base.
                        if l.dtype == DType::F64
                            && l.shape == n.shape
                            && !l.shape.is_scalar()
                            && Rc::strong_count(&l) <= 2
                        {
                            // base: held by its parent op edge (1) and at
                            // most our transient clone — no user handle,
                            // no other consumer.
                            let mut ops = 0usize;
                            let tree =
                                self.build_tree_children_acc(n, kernel_space(&n.shape), &l, &mut ops);
                            return Some(Step::Accumulate { out: n.clone(), base: l, tree });
                        }
                        return None;
                    } else if self.an.is_private_temp(&l)
                        && !self.planned.contains(&l.id)
                        && matches!(&*l.op.borrow(), Op::Bin(BinOp::Add, ..) | Op::Bin(BinOp::Sub, ..))
                    {
                        spine.push(l);
                        // bounded: MAX_FUSE_OPS guards tree size later;
                        // spine depth only costs this walk.
                        if spine.len() > MAX_FUSE_OPS {
                            return None;
                        }
                        continue;
                    }
                    return None;
                }
                _ => return None,
            }
        }
    }

    /// Fused tree for `n`'s children combined by `n`'s element-wise op.
    fn build_tree_children(&mut self, n: &NodeRef, v: View, ops: &mut usize) -> FTree {
        let op = n.op.borrow();
        match &*op {
            Op::Bin(b, l, r) => {
                let (b, l, r) = (*b, l.clone(), r.clone());
                drop(op);
                *ops += 1;
                let lt = self.build_tree(&l, v, ops, false);
                let rt = self.build_tree(&r, v, ops, false);
                FTree::Bin(b, Box::new(lt), Box::new(rt))
            }
            Op::Un(u, c) => {
                let (u, c) = (*u, c.clone());
                drop(op);
                *ops += 1;
                let ct = self.build_tree(&c, v, ops, false);
                FTree::Un(u, Box::new(ct))
            }
            _ => {
                drop(op);
                self.build_tree(n, v, ops, true)
            }
        }
    }

    /// Like [`build_tree_children`] but replacing the base leaf with `Acc`.
    fn build_tree_children_acc(
        &mut self,
        n: &NodeRef,
        v: View,
        base: &NodeRef,
        ops: &mut usize,
    ) -> FTree {
        if n.id == base.id {
            return FTree::Acc;
        }
        let op = n.op.borrow();
        match &*op {
            Op::Bin(b, l, r) => {
                let (b, l, r) = (*b, l.clone(), r.clone());
                drop(op);
                *ops += 1;
                let lt = if l.id == base.id {
                    FTree::Acc
                } else if !l.is_materialized()
                    && self.an.is_private_temp(&l)
                    && !self.planned.contains(&l.id)
                {
                    self.build_tree_children_acc(&l, v, base, ops)
                } else {
                    self.build_tree(&l, v, ops, false)
                };
                let rt = self.build_tree(&r, v, ops, false);
                FTree::Bin(b, Box::new(lt), Box::new(rt))
            }
            _ => {
                drop(op);
                self.build_tree(n, v, ops, false)
            }
        }
    }

    /// Build the fused tree for operand `n` viewed through `v`.
    ///
    /// `force_copy`: build an identity-copy tree even if `n` itself is a
    /// view (used when a view is promoted to a materialisation root).
    fn build_tree(&mut self, n: &NodeRef, v: View, ops: &mut usize, force_copy: bool) -> FTree {
        // Scalars broadcast.
        if n.shape.is_scalar() {
            if let Some(c) = const_value(n) {
                return FTree::Const(c);
            }
            self.ensure(n);
            return FTree::ScalarLeaf { node: n.clone() };
        }
        if n.is_materialized() {
            return FTree::Leaf { node: n.clone(), view: v };
        }
        let op = n.op.borrow();
        match &*op {
            Op::Source(_) => {
                drop(op);
                FTree::Leaf { node: n.clone(), view: v }
            }
            Op::Iota(_) => {
                drop(op);
                if v.is_contiguous() && v.base == 0 {
                    FTree::Iota
                } else {
                    self.ensure(n);
                    FTree::Leaf { node: n.clone(), view: v }
                }
            }
            // A gather absorbed into its consumer's loop: the tape VM's
            // monomorphised gather loader reads `src[idx[base + k]]`
            // directly, so the index traffic happens inside the fused
            // pass instead of through a materialised temporary. Only
            // contiguous views compose (the spmv case: the segmented
            // reduce evaluates its operand over the flat nnz space).
            Op::Gather { src, idx } => {
                let fusable = self.opts.fusion
                    && self.an.is_private_temp(n)
                    && !self.planned.contains(&n.id)
                    && v.is_contiguous()
                    && !force_copy;
                let (src, idx) = (src.clone(), idx.clone());
                drop(op);
                if fusable {
                    self.ensure(&src);
                    self.ensure(&idx);
                    FTree::Gather { src, idx, base: v.base }
                } else {
                    self.ensure(n);
                    FTree::Leaf { node: n.clone(), view: v }
                }
            }
            Op::Bin(..) | Op::Un(..) => {
                let fusable = self.opts.fusion
                    && self.an.is_private_temp(n)
                    && !self.planned.contains(&n.id)
                    && *ops < MAX_FUSE_OPS;
                drop(op);
                if fusable && !force_copy {
                    // Only fuse through non-reshaping views: an element-wise
                    // op evaluated under view `v` computes op(children@v),
                    // which is sound for any affine v.
                    self.build_tree_children_viewed(n, v, ops)
                } else {
                    self.ensure(n);
                    FTree::Leaf { node: n.clone(), view: v }
                }
            }
            _ if op.is_virtual_view() && !force_copy => {
                let composed = compose(&op, &v);
                let child = op.children().pop();
                drop(op);
                match (composed, child) {
                    (Some(cv), Some(c)) => {
                        let mut cv = cv;
                        // The child is indexed in its own flat space; keep
                        // the output-space geometry of `v`.
                        cv.out_cols = v.out_cols;
                        self.build_tree(&c, cv, ops, false)
                    }
                    _ => {
                        // Unrepresentable composition: materialise `n`.
                        self.ensure(n);
                        FTree::Leaf { node: n.clone(), view: v }
                    }
                }
            }
            _ => {
                drop(op);
                self.ensure(n);
                FTree::Leaf { node: n.clone(), view: v }
            }
        }
    }

    /// Element-wise node evaluated under an arbitrary affine view: fuse
    /// children under the same view.
    fn build_tree_children_viewed(&mut self, n: &NodeRef, v: View, ops: &mut usize) -> FTree {
        let op = n.op.borrow();
        match &*op {
            Op::Bin(b, l, r) => {
                let (b, l, r) = (*b, l.clone(), r.clone());
                drop(op);
                *ops += 1;
                let lt = self.build_tree(&l, v, ops, false);
                let rt = self.build_tree(&r, v, ops, false);
                FTree::Bin(b, Box::new(lt), Box::new(rt))
            }
            Op::Un(u, c) => {
                let (u, c) = (*u, c.clone());
                drop(op);
                *ops += 1;
                let ct = self.build_tree(&c, v, ops, false);
                FTree::Un(u, Box::new(ct))
            }
            _ => unreachable!("caller checked Bin/Un"),
        }
    }
}

/// Constant value of a node if it is a (possibly folded) scalar constant.
pub fn const_value(n: &Node) -> Option<f64> {
    match &*n.op.borrow() {
        Op::ConstF64(c) => Some(*c),
        Op::Source(d) if n.shape.is_scalar() => match d {
            super::node::Data::F64(v) => v.first().copied(),
            _ => None,
        },
        _ => None,
    }
}

/// Count fused-op statistics of a plan (used by tests and ablations).
pub fn plan_fused_ops(p: &Plan) -> usize {
    p.steps
        .iter()
        .map(|s| match s {
            Step::Fused { tree, .. } | Step::Accumulate { tree, .. } => tree.count_ops(),
            Step::ReduceRows { tree, .. }
            | Step::ReduceCols { tree, .. }
            | Step::ReduceAll { tree, .. }
            | Step::SegmentedReduce { tree, .. } => tree.count_ops(),
            Step::Cat { a, b, .. } => a.count_ops() + b.count_ops(),
            _ => 0,
        })
        .sum()
}
