//! mod2am — dense matrix–matrix multiply (§3.1): all four DSL versions
//! vs the MKL-analog and the naive serial loop, on one size.
//!
//! ```sh
//! cargo run --release --example mod2am -- [n]
//! ```

use arbb_rs::bench::{mflops, time_best};
use arbb_rs::coordinator::Context;
use arbb_rs::euroben::mod2am::*;
use arbb_rs::kernels::{dgemm, dgemm_naive, gemm_flops};
use arbb_rs::util::{assert_allclose, XorShift64};

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let mut rng = XorShift64::new(42);
    let ah: Vec<f64> = (0..n * n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let bh: Vec<f64> = (0..n * n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let flops = gemm_flops(n, n, n);

    println!("mod2am n={n} ({} MFlop per multiply)\n", (flops * 1e-6) as u64);

    // references
    let mut want = vec![0.0; n * n];
    let t_mkl = time_best(|| dgemm(n, n, n, &ah, &bh, &mut want), 0.3, 2);
    let mut naive = vec![0.0; n * n];
    let t_omp = time_best(|| dgemm_naive(n, n, n, &ah, &bh, &mut naive), 0.3, 2);
    assert_allclose(&naive, &want, 1e-10, 1e-11, "naive vs blocked");

    println!("  {:<22} {:>10.1} MFlop/s", "native blocked (MKL~)", mflops(flops, t_mkl));
    println!("  {:<22} {:>10.1} MFlop/s", "naive serial (OMP 1T)", mflops(flops, t_omp));

    let ctx = Context::serial();
    let a = ctx.bind2(&ah, n, n);
    let b = ctx.bind2(&bh, n, n);

    let variants: Vec<(&str, Box<dyn Fn() -> Vec<f64>>)> = vec![
        ("arbb_mxm1", Box::new(|| arbb_mxm1(&ctx, &a, &b).to_vec())),
        ("arbb_mxm2a", Box::new(|| arbb_mxm2a(&a, &b).to_vec())),
        ("arbb_mxm2b(u=8)", Box::new(|| arbb_mxm2b(&a, &b, 8).to_vec())),
    ];
    for (name, f) in &variants {
        let got = f();
        assert_allclose(&got, &want, 1e-9, 1e-10, name);
        let t = time_best(
            || {
                let _ = f();
            },
            0.3,
            2,
        );
        println!("  {:<22} {:>10.1} MFlop/s", name, mflops(flops, t));
    }

    // mxm0 only for small n (per-element dispatch, like the paper's slow curve)
    if n <= 128 {
        let got = arbb_mxm0(&ctx, &a, &b).to_vec();
        assert_allclose(&got, &want, 1e-9, 1e-10, "arbb_mxm0");
        let t = time_best(
            || {
                let _ = arbb_mxm0(&ctx, &a, &b).to_vec();
            },
            0.3,
            1,
        );
        println!("  {:<22} {:>10.1} MFlop/s", "arbb_mxm0", mflops(flops, t));
    } else {
        println!("  {:<22} {:>10}", "arbb_mxm0", "(skipped, n>128)");
    }
    println!("\nmod2am OK — see `cargo bench --bench fig1_mod2am` for the full figure");
}
