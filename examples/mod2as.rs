//! mod2as — sparse matrix–vector multiply (§3.2): arbb_spmv1/2 (now
//! first-class gather + segmented-sum ops on the tape VM) vs the
//! MKL-analog (serial and pooled row panels) and both OpenMP loop
//! bodies. The DSL outputs are asserted bit-identical to the retained
//! tree-interpreter reference.
//!
//! ```sh
//! cargo run --release --example mod2as -- [n] [fill%]
//! ```

use arbb_rs::bench::{mflops, time_best};
use arbb_rs::coordinator::engine::pool;
use arbb_rs::coordinator::Context;
use arbb_rs::euroben::mod2as::*;
use arbb_rs::kernels::{spmv_flops, spmv_omp1_body, spmv_omp2_body, spmv_opt, spmv_pooled};
use arbb_rs::sparse::random_csr;
use arbb_rs::util::assert_allclose;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4096);
    let fill: f64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(4.5);
    let m = random_csr(n, fill, 42);
    let x = m.random_x(7);
    let flops = spmv_flops(&m);
    println!(
        "mod2as n={n} fill={:.2}% nnz={} contiguity(≥2)={:.1}%\n",
        m.fill_percent(),
        m.nnz(),
        100.0 * m.contiguity(2)
    );

    let want = m.spmv_alloc(&x);
    let mut out = vec![0.0; n];

    let t = time_best(|| spmv_opt(&m, &x, &mut out), 0.2, 3);
    assert_allclose(&out, &want, 1e-12, 1e-13, "mkl");
    println!("  {:<16} {:>10.1} MFlop/s", "mkl_dcsrmv~", mflops(flops, t));

    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let p = pool::shared(workers);
    let t = time_best(|| spmv_pooled(&m, &x, &mut out, &p), 0.2, 3);
    assert_allclose(&out, &want, 1e-12, 1e-13, "pooled");
    println!(
        "  {:<16} {:>10.1} MFlop/s  ({} workers, nnz-balanced panels)",
        "pooled panels",
        mflops(flops, t),
        workers
    );

    let t = time_best(|| spmv_omp1_body(&m, &x, &mut out), 0.2, 3);
    println!("  {:<16} {:>10.1} MFlop/s", "OMP1 body", mflops(flops, t));
    let t = time_best(|| spmv_omp2_body(&m, &x, &mut out), 0.2, 3);
    println!("  {:<16} {:>10.1} MFlop/s", "OMP2 body", mflops(flops, t));

    // The retained tree-interpreter reference: every DSL executor path
    // must reproduce it bit-for-bit.
    let reference = spmv_seg_reference(&m, &x);
    assert_allclose(&reference, &want, 1e-12, 1e-13, "seg reference");

    let ctx = Context::serial();
    let a = bind_csr(&ctx, &m);
    let xv = ctx.bind1(&x);
    let got = arbb_spmv1(&ctx, &a, &xv).to_vec();
    for r in 0..n {
        assert_eq!(got[r].to_bits(), reference[r].to_bits(), "spmv1 diverges at row {r}");
    }
    let t = time_best(
        || {
            let _ = arbb_spmv1(&ctx, &a, &xv).to_vec();
        },
        0.2,
        3,
    );
    println!("  {:<16} {:>10.1} MFlop/s", "arbb_spmv1", mflops(flops, t));

    let got = arbb_spmv2(&ctx, &a, &xv).to_vec();
    for r in 0..n {
        assert_eq!(got[r].to_bits(), reference[r].to_bits(), "spmv2 diverges at row {r}");
    }
    let t = time_best(
        || {
            let _ = arbb_spmv2(&ctx, &a, &xv).to_vec();
        },
        0.2,
        3,
    );
    println!("  {:<16} {:>10.1} MFlop/s", "arbb_spmv2", mflops(flops, t));

    let pctx = Context::parallel(workers);
    let pa = bind_csr(&pctx, &m);
    let px = pctx.bind1(&x);
    let got = arbb_spmv1(&pctx, &pa, &px).to_vec();
    for r in 0..n {
        assert_eq!(got[r].to_bits(), reference[r].to_bits(), "O3 spmv1 diverges at row {r}");
    }
    let t = time_best(
        || {
            let _ = arbb_spmv1(&pctx, &pa, &px).to_vec();
        },
        0.2,
        3,
    );
    println!("  {:<16} {:>10.1} MFlop/s", "arbb_spmv1 O3", mflops(flops, t));

    println!("\nmod2as OK (DSL bit-identical to the tree-interpreter reference)");
    println!("see `cargo bench --bench fig2_mod2as` for the full figure");
}
