//! Chaos-leg probe for the live observability plane: a small server
//! with the scrape endpoints bound on an ephemeral port, driven from a
//! CI shell by file handshakes while `curl` watches `/healthz` and
//! `/readyz` flip and recover around an injected quarantine trip.
//!
//! Protocol (all paths inside the handshake directory, argv[1],
//! default `.`):
//!
//!  1. the probe writes `obs_addr.txt` once the listener is bound and
//!     serves healthy warm-up traffic — the shell asserts `/readyz`
//!     answers 200;
//!  2. the shell touches `fault.go`; the probe arms a deterministic
//!     capture failpoint and calls the (still uncaptured) `poison`
//!     kernel until its plan circuit breaker trips — readiness flips
//!     to 503 — then touches `tripped.ok`;
//!  3. the shell watches `/readyz` recover once the quarantine backoff
//!     elapses, then touches `done.go`; the probe exits 0. The healthy
//!     `ok` kernel serves cached replays through the whole episode.
//!
//! ```sh
//! cargo run --release --example obs_chaos_probe -- /tmp/obs_probe
//! ```

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use arbb_rs::obs::faults::{self, FaultSpec};
use arbb_rs::serve::{Arg, ObsConfig, ResilienceConfig, ServeConfig, ServeError, Server, Value};

/// Handshake timeout: generous for cold CI runners, finite so a broken
/// driver script fails the job instead of hanging it.
const HANDSHAKE: Duration = Duration::from_secs(120);

fn wait_for(path: &Path, what: &str) {
    let deadline = Instant::now() + HANDSHAKE;
    while !path.exists() {
        assert!(Instant::now() < deadline, "timed out waiting for {what} ({path:?})");
        std::thread::sleep(Duration::from_millis(100));
    }
}

fn main() {
    let dir = PathBuf::from(std::env::args().nth(1).unwrap_or_else(|| ".".into()));
    std::fs::create_dir_all(&dir).expect("handshake dir");

    let server = Server::builder(ServeConfig {
        workers: 1,
        resilience: ResilienceConfig {
            quarantine_threshold: 2,
            quarantine_backoff: Duration::from_secs(3),
            // Disarm whatever `PALLAS_FAULTS` installed: the chaos job
            // runs with probabilistic pool faults that could trip the
            // *healthy* kernel's breaker at random. The probe injects
            // its own deterministic capture failure in phase 2 instead,
            // so the readiness flip happens exactly once, on cue.
            faults: Some(FaultSpec { points: Vec::new(), seed: 0 }),
            ..ResilienceConfig::default()
        },
        obs: ObsConfig {
            listen_addr: Some("127.0.0.1:0".to_string()),
            trace_capacity: 256,
            ..ObsConfig::default()
        },
        ..ServeConfig::serial()
    })
    .kernel("ok", |_ctx, p| Value::Vec(p[0].vec1().scale(2.0)))
    .kernel("poison", |_ctx, p| Value::Vec(p[0].vec1().scale(1.0)))
    .start();

    let addr = server.obs_addr().expect("obs listener bound");
    let client = server.client();
    let args = || vec![Arg::vec(vec![1.0; 64])];

    // Phase 1: healthy traffic, then publish the scrape address.
    for _ in 0..5 {
        client.call("ok", args()).expect("healthy warm-up call");
    }
    std::fs::write(dir.join("obs_addr.txt"), addr.to_string()).expect("write obs_addr.txt");
    println!("obs_chaos_probe: serving on {addr}, waiting for fault.go");

    // Phase 2: every capture now fails deterministically; the poison
    // plan (never captured, so never cached) trips its breaker after
    // two consecutive failures.
    wait_for(&dir.join("fault.go"), "fault.go");
    faults::install(&FaultSpec::parse("serve.capture.fail:1.0", 42).expect("failpoint spec"));
    let mut attempts = 0u32;
    loop {
        match client.call("poison", args()) {
            Err(ServeError::Quarantined { failures, .. }) => {
                println!("obs_chaos_probe: breaker tripped after {failures} failures");
                break;
            }
            Err(e) => {
                attempts += 1;
                assert!(e.is_injected(), "expected the injected capture failure, got {e}");
                assert!(attempts <= 5, "breaker never tripped");
            }
            Ok(_) => panic!("capture failpoint is armed; poison cannot capture"),
        }
    }
    faults::clear();
    std::fs::write(dir.join("tripped.ok"), "tripped\n").expect("write tripped.ok");

    // Phase 3: keep the healthy tenant replaying its cached plan while
    // the shell watches `/readyz` recover after the backoff.
    let done = dir.join("done.go");
    let deadline = Instant::now() + HANDSHAKE;
    while !done.exists() {
        assert!(Instant::now() < deadline, "timed out waiting for done.go");
        assert_eq!(
            client.call("ok", args()).expect("healthy kernel during quarantine")[0],
            2.0,
            "cached replay must stay correct"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
    println!("obs_chaos_probe: done; {} flight dump(s) frozen", client.flight_dumps().len());
}
