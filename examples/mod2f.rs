//! mod2f — 1-D complex FFT (§3.3): the split-stream DSL port vs the
//! serial radix-2, serial split-stream, CFFT4-analog and the planned
//! (MKL-analog) FFT.
//!
//! ```sh
//! cargo run --release --example mod2f -- [log2n]
//! ```

use arbb_rs::bench::{mflops, time_best};
use arbb_rs::coordinator::{Context, CplxV};
use arbb_rs::euroben::mod2f;
use arbb_rs::fftlib::{fft_flops, radix2, radix4, splitstream};
use arbb_rs::kernels::fft_planned;
use arbb_rs::util::{assert_allclose, XorShift64};

fn main() {
    let logn: u32 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(14);
    let n = 1usize << logn;
    let mut rng = XorShift64::new(42);
    let re: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let im: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let flops = fft_flops(n);
    println!("mod2f n={n} (2^{logn})\n");

    let (wre, wim) = fft_planned(&re, &im);

    let t = time_best(
        || {
            let _ = fft_planned(&re, &im);
        },
        0.2,
        3,
    );
    println!("  {:<20} {:>10.1} MFlop/s", "MKL~ (planned)", mflops(flops, t));

    let (r4re, _) = radix4::fft(&re, &im);
    assert_allclose(&r4re, &wre, 1e-8, 1e-8, "radix4");
    let t = time_best(
        || {
            let _ = radix4::fft(&re, &im);
        },
        0.2,
        3,
    );
    println!("  {:<20} {:>10.1} MFlop/s", "CFFT4~ (radix-4+2)", mflops(flops, t));

    let t = time_best(
        || {
            let _ = radix2::fft(&re, &im);
        },
        0.2,
        3,
    );
    println!("  {:<20} {:>10.1} MFlop/s", "simple radix-2", mflops(flops, t));

    let t = time_best(
        || {
            let _ = splitstream::fft(&re, &im);
        },
        0.2,
        3,
    );
    println!("  {:<20} {:>10.1} MFlop/s", "serial split-stream", mflops(flops, t));

    let ctx = Context::serial();
    let plan = mod2f::plan(&ctx, n);
    let data = CplxV { re: ctx.bind1(&re), im: ctx.bind1(&im) };
    let out = mod2f::arbb_fft(&plan, &data);
    assert_allclose(&out.re.to_vec(), &wre, 1e-8, 1e-8, "dsl re");
    assert_allclose(&out.im.to_vec(), &wim, 1e-8, 1e-8, "dsl im");
    let t = time_best(
        || {
            let out = mod2f::arbb_fft(&plan, &data);
            out.re.eval();
        },
        0.2,
        3,
    );
    println!("  {:<20} {:>10.1} MFlop/s", "arbb split-stream", mflops(flops, t));

    // Whole-kernel capture (arbb::call): the full stage loop captured
    // once into a Program — double-buffered planes, no cat
    // materialisation — then replayed per call from a recycled state.
    let fp = mod2f::capture_fft(n);
    let (cre, cim) = fp.run(&re, &im);
    let eref = (out.re.to_vec(), out.im.to_vec());
    for k in 0..n {
        assert!(
            cre[k].to_bits() == eref.0[k].to_bits() && cim[k].to_bits() == eref.1[k].to_bits(),
            "captured program diverges from the eager stage loop at {k}"
        );
    }
    let mut buf = Vec::new();
    let t = time_best(|| fp.run_into(&re, &im, &mut buf).unwrap(), 0.2, 3);
    println!(
        "  {:<20} {:>10.1} MFlop/s   ({} slots, {} replays / {} state)",
        "arbb captured call",
        mflops(flops, t),
        fp.program().n_slots(),
        fp.program().stats().replays,
        fp.program().stats().states_created
    );

    println!("\nmod2f OK — see `cargo bench --bench fig5_fft` for the full figure");
}
