//! Plan-store probe for the CI warm-restart leg: a tiny server whose
//! plan store comes from `PALLAS_PLAN_STORE` (no explicit path in the
//! config), serving one explored kernel and printing the planner
//! counters in a grep-friendly form. The CI leg runs this binary twice
//! against the same store file: the first run must report a cold start
//! (calibration plus one exploration), the second a warm start with
//! zero calibration seconds and zero explorations — the
//! restart-without-warmup acceptance of the plan-store subsystem.
//!
//! ```sh
//! PALLAS_PLAN_STORE=/tmp/pallas.planstore \
//!     cargo run --release --example plan_store_probe
//! ```

use arbb_rs::euroben::mod2as;
use arbb_rs::serve::{Arg, ObsConfig, ServeConfig, Server, Value};
use arbb_rs::sparse::banded_spd;
use arbb_rs::util::assert_allclose;

fn main() {
    let cfg = ServeConfig {
        obs: ObsConfig { tape_profile: true, ..ObsConfig::default() },
        ..ServeConfig::serial()
    };
    let store = cfg.effective_plan_store().unwrap_or_else(|| "(none)".into());

    let m = banded_spd(96, 5, 3);
    let m2 = m.clone();
    let server = Server::builder(cfg)
        .kernel("spmv", move |ctx, p| {
            let a = mod2as::bind_csr(ctx, &m2);
            Value::Vec(mod2as::arbb_spmv1(ctx, &a, &p[0].vec1()))
        })
        .start();
    let client = server.client();

    // Serve a few shapes-identical requests; the first resolves the
    // plan (memo hit on a warm store, exploration on a cold one), the
    // rest are pure replays. Correctness is asserted either way.
    for seed in 0..3u64 {
        let x = m.random_x(seed);
        let want = m.spmv_alloc(&x);
        let got = client.call("spmv", vec![Arg::vec(x)]).expect("serve spmv");
        assert_allclose(&got, &want, 1e-11, 1e-12, "probe spmv");
    }

    let st = client.planner_stats().expect("planner is on by default");
    println!("store={store}");
    println!(
        "planner: warm_start={} calib_secs={:.6} explorations={} memo_hits={} memo_len={} \
         backend={}",
        st.warm_start, st.calib_secs, st.explorations, st.memo_hits, st.memo_len, st.backend
    );
    for d in client.planner_decisions() {
        println!(
            "decision: key={} variant={} est_ns_per_elem={:.4} measured_ns_per_elem={:.4}",
            d.key, d.variant, d.est_ns_per_elem, d.measured_ns_per_elem
        );
    }
}
