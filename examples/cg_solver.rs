//! Conjugate gradients (§3.4): solve a banded SPD system with the DSL CG
//! (both spmv variants), the serial CG, the MKL-analog CG, and for
//! completeness the Jacobi / Gauss–Seidel solvers the paper also ported.
//!
//! ```sh
//! cargo run --release --example cg_solver -- [n] [bw]
//! ```

use arbb_rs::bench::time_best;
use arbb_rs::coordinator::Context;
use arbb_rs::euroben::cg::{arbb_cg, SpmvVariant};
use arbb_rs::euroben::mod2as::bind_csr;
use arbb_rs::solvers::{cg_mkl, cg_serial, gauss_seidel, jacobi, residual_norm};
use arbb_rs::sparse::banded_spd;
use arbb_rs::util::XorShift64;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1024);
    let bw: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(63);
    let a = banded_spd(n, bw, 42);
    let mut rng = XorShift64::new(7);
    let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let stop = 1e-16;
    let max_iters = 4 * n;
    println!("cg_solver n={n} bw={bw} nnz={}\n", a.nnz());

    let res = cg_serial(&a, &b, stop, max_iters);
    println!(
        "  {:<18} iters={:<4} |Ax-b|={:.2e}",
        "serial CG",
        res.iterations,
        residual_norm(&a, &res.x, &b)
    );
    let t = time_best(
        || {
            let _ = cg_serial(&a, &b, stop, max_iters);
        },
        0.2,
        2,
    );
    println!("  {:<18} {:>10.2} ms/solve", "", t * 1e3);

    let res = cg_mkl(&a, &b, stop, max_iters);
    let t = time_best(
        || {
            let _ = cg_mkl(&a, &b, stop, max_iters);
        },
        0.2,
        2,
    );
    println!("  {:<18} iters={:<4} {:>10.2} ms/solve", "CG + mkl spmv", res.iterations, t * 1e3);

    let ctx = Context::serial();
    let ac = bind_csr(&ctx, &a);
    for (name, variant) in [("CG + arbb_spmv1", SpmvVariant::V1), ("CG + arbb_spmv2", SpmvVariant::V2)]
    {
        let res = arbb_cg(&ctx, &ac, &b, stop, max_iters, variant);
        assert!(res.converged);
        let t = time_best(
            || {
                let _ = arbb_cg(&ctx, &ac, &b, stop, max_iters, variant);
            },
            0.2,
            2,
        );
        println!(
            "  {:<18} iters={:<4} {:>10.2} ms/solve  |Ax-b|={:.2e}",
            name,
            res.iterations,
            t * 1e3,
            residual_norm(&a, &res.x, &b)
        );
    }

    // the other solvers the paper ported
    let ja = jacobi(&a, &b, stop, 100_000);
    println!("  {:<18} iters={:<6} |Ax-b|={:.2e}", "Jacobi", ja.iterations, residual_norm(&a, &ja.x, &b));
    let gs = gauss_seidel(&a, &b, stop, 100_000);
    println!(
        "  {:<18} iters={:<6} |Ax-b|={:.2e}",
        "Gauss-Seidel",
        gs.iterations,
        residual_norm(&a, &gs.x, &b)
    );

    println!("\ncg_solver OK — see `cargo bench --bench fig7_cg` for the full figure");
}
