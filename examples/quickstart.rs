//! Quickstart: the DSL in five minutes.
//!
//! Mirrors §2/§3.1 of the paper: bind host data into containers, express
//! the computation with serial semantics, read back. Run with:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use arbb_rs::coordinator::{Context, Options, OptLevel};

fn main() {
    // 1. a context — the ArBB runtime handle (O2 = vectorised serial).
    let ctx = Context::new();

    // 2. bind host data into "ArBB space" (dense containers).
    let a = ctx.bind1(&[1.0, 2.0, 3.0, 4.0]);
    let b = ctx.bind1(&[10.0, 20.0, 30.0, 40.0]);

    // 3. math-like expressions build a captured IR; nothing executes yet.
    let c = (&a + &b).scale(0.5); // (a+b)/2
    let norm = c.dot(&c).sqrt(); // scalar reduction

    // 4. reading forces the optimiser + engine.
    println!("c     = {:?}", c.to_vec());
    println!("‖c‖   = {:.4}", norm.value());

    // 5. matrices: the paper's mxm1 formulation on a 4×4 example.
    let n = 4;
    let m = ctx.bind2(&(0..16).map(|x| x as f64).collect::<Vec<_>>(), n, n);
    let eye = {
        let mut e = vec![0.0; n * n];
        for i in 0..n {
            e[i * n + i] = 1.0;
        }
        ctx.bind2(&e, n, n)
    };
    let mut prod = ctx.zeros2(n, n);
    for i in 0..n {
        let t = eye.col(i).repeat_row(n);
        let d = &m * &t;
        prod = prod.replace_col(i, &d.add_reduce_rows());
    }
    println!("M·I row 2 = {:?}", &prod.to_vec()[2 * n..3 * n]);

    // 6. switch to the threaded engine (O3 + ARBB_NUM_CORES analog).
    let par = Context::with_options(Options {
        opt_level: OptLevel::O3,
        num_workers: 4,
        ..Default::default()
    });
    let big: Vec<f64> = (0..1_000_000).map(|x| x as f64 * 1e-6).collect();
    let v = par.bind1(&big);
    let s = ((&v * &v) - &v).add_reduce().value();
    println!("Σ v²-v    = {s:.3} (threaded engine)");

    // 7. engine statistics — dispatches, steps, fused flops.
    par.stats(|st| {
        println!(
            "stats: forces={} steps={} elements={} flops={:.1e}",
            st.forces, st.steps, st.elements, st.flops
        );
    });
    println!("quickstart OK");
}
