//! Serving driver: the four EuroBen kernels behind the `serve`
//! subsystem, hammered concurrently by client threads.
//!
//! Demonstrates the capture-once / call-many serving model end to end:
//!
//!  * **mod2am** — dense matmul via rank-1 updates (mxm2a formulation,
//!    capture-pure: no per-iteration forces; the plan fuses the update
//!    chain once and every request replays it);
//!  * **mod2as** — CSR spmv in first-class ops (gather + segmented sum,
//!    compiled to the fused `GatherMulSegSum` tape path) with the matrix
//!    structure *baked* into the plan and the input vector as the
//!    parameter — a cache-hit replay allocates nothing;
//!  * **mod2f**  — split-stream FFT, twiddles + tangling baked;
//!  * **cg8**    — 8 fixed conjugate-gradient iterations with
//!    alpha/beta kept in ArBB space (no host syncs → capturable).
//!
//! Each kernel is verified against its native reference, then client
//! threads flood the bounded queue (QueueFull → retry) and the serving
//! report is printed: throughput, p50/p99 latency, batch sizes and plan
//! cache hit rates.
//!
//! ```sh
//! cargo run --release --example serve_euroben
//! ```

use std::sync::Arc;
use std::time::Instant;

use arbb_rs::coordinator::{Context, Vec1};
use arbb_rs::euroben::{mod2am, mod2as};
use arbb_rs::fftlib::dft_ref;
use arbb_rs::serve::{Arg, ServeConfig, Server, SubmitError, Value};
use arbb_rs::sparse::{banded_spd, random_csr};
use arbb_rs::util::{assert_allclose, XorShift64};

const MXM_N: usize = 48;
const SPMV_N: usize = 1024;
const FFT_N: usize = 256;
const CG_N: usize = 256;
const CG_ITERS: usize = 8;

/// Capture-pure rank-1-update matmul (mxm2a without the `_for` forces).
fn mxm_kernel(params: &[Value]) -> Value {
    let a = params[0].mat2();
    let b = params[1].mat2();
    let n = a.rows();
    let mut c = a.col(0).repeat_col(n) * &b.row(0).repeat_row(n);
    for i in 1..n {
        c = c + (a.col(i).repeat_col(n) * &b.row(i).repeat_row(n));
    }
    Value::Mat(c)
}

/// Fixed-iteration CG: everything stays in ArBB space, so the whole
/// solver captures as one plan.
fn cg_fixed(ctx: &Context, a: &mod2as::ArbbCsr, b: &Vec1, iters: usize) -> Vec1 {
    let n = b.len();
    let mut x = ctx.zeros1(n);
    let mut r = b.clone();
    let mut p = b.clone();
    let mut r2 = r.dot(&r);
    for _ in 0..iters {
        let ap = mod2as::arbb_spmv1(ctx, a, &p);
        let pap = p.dot(&ap);
        let alpha = &r2 / &pap;
        x = &x + &(&p * &alpha);
        let rn = &r - &(&ap * &alpha);
        let r2n = rn.dot(&rn);
        let beta = &r2n / &r2;
        p = &rn + &(&p * &beta);
        r = rn;
        r2 = r2n;
    }
    x
}

fn main() {
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(8);
    println!("=== serve_euroben: EuroBen kernels behind the serving subsystem ===");
    println!("    workers={workers}, bounded queue, batching dispatcher\n");

    // Host-side fixtures baked into the kernels.
    let spmv_m = Arc::new(random_csr(SPMV_N, 100.0 * 16.0 / SPMV_N as f64, 11));
    let cg_m = Arc::new(banded_spd(CG_N, 7, 5));
    let spmv_m2 = spmv_m.clone();
    let cg_m2 = cg_m.clone();

    let server = Server::builder(ServeConfig {
        workers,
        queue_capacity: 128,
        max_batch: 16,
        ..ServeConfig::default()
    })
    .kernel("mod2am", |_ctx, params| mxm_kernel(params))
    .kernel("mod2as", move |ctx, params| {
        let a = mod2as::bind_csr(ctx, &spmv_m2);
        Value::Vec(mod2as::arbb_spmv1(ctx, &a, &params[0].vec1()))
    })
    .kernel("mod2f", |ctx, params| {
        let re = params[0].vec1();
        let im = params[1].vec1();
        let n = re.len();
        // split-stream stage loop, capture-pure (no per-stage forces);
        // tangle indices + twiddle tables are baked into the plan
        let tg = tangle(ctx, n);
        let mut d = arbb_rs::coordinator::CplxV { re: re.gather(&tg), im: im.gather(&tg) };
        let (twre, twim) = twiddles(ctx, n);
        let h = n / 2;
        let mut m = h;
        let mut i = 1;
        while i < n {
            let even = d.section_strided(0, h, 2);
            let odd = d.section_strided(1, h, 2);
            let up = even.add(&odd);
            let tw = arbb_rs::coordinator::CplxV {
                re: twre.section(0, m).repeat(i),
                im: twim.section(0, m).repeat(i),
            };
            let down = even.sub(&odd).mul(&tw);
            d = up.cat(&down);
            m >>= 1;
            i <<= 1;
        }
        Value::Vec(d.re.cat(&d.im))
    })
    .kernel("cg8", move |ctx, params| {
        let a = mod2as::bind_csr(ctx, &cg_m2);
        Value::Vec(cg_fixed(ctx, &a, &params[0].vec1(), CG_ITERS))
    })
    .start();

    let client = server.client();

    // ---- verify one response per kernel against the references ----
    println!("[1/3] verifying served results against native references …");
    let mut rng = XorShift64::new(1);

    let ah: Vec<f64> = (0..MXM_N * MXM_N).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let bh: Vec<f64> = (0..MXM_N * MXM_N).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let got = client
        .call("mod2am", vec![Arg::mat(ah.clone(), MXM_N, MXM_N), Arg::mat(bh.clone(), MXM_N, MXM_N)])
        .expect("mod2am");
    assert_allclose(&got, &mod2am::reference(&ah, &bh, MXM_N), 1e-10, 1e-11, "serve mod2am");

    let xs = spmv_m.random_x(3);
    let got = client.call("mod2as", vec![Arg::vec(xs.clone())]).expect("mod2as");
    assert_allclose(&got, &spmv_m.spmv_alloc(&xs), 1e-11, 1e-12, "serve mod2as");

    let fre: Vec<f64> = (0..FFT_N).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let fim: Vec<f64> = (0..FFT_N).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let got = client
        .call("mod2f", vec![Arg::vec(fre.clone()), Arg::vec(fim.clone())])
        .expect("mod2f");
    let (wre, wim) = dft_ref::dft(&fre, &fim);
    assert_allclose(&got[..FFT_N], &wre, 1e-8, 1e-8, "serve fft re");
    assert_allclose(&got[FFT_N..], &wim, 1e-8, 1e-8, "serve fft im");

    let cb: Vec<f64> = (0..CG_N).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let got = client.call("cg8", vec![Arg::vec(cb.clone())]).expect("cg8");
    let native = arbb_rs::solvers::cg_fixed_iters(&cg_m, &cb, CG_ITERS);
    assert_allclose(&got, &native, 1e-8, 1e-9, "serve cg8");
    println!("      all four kernels verified\n");

    // ---- concurrent hammer ----
    println!("[2/3] hammering all four kernels from {} client threads …", 2 * 4);
    let run_secs = 2.0;
    let mut handles = Vec::new();
    for t in 0..8usize {
        let client = server.client();
        let spmv_m = spmv_m.clone();
        let (ah, bh) = (ah.clone(), bh.clone());
        let (fre, fim) = (fre.clone(), fim.clone());
        let cb = cb.clone();
        handles.push(std::thread::spawn(move || {
            let kernel = ["mod2am", "mod2as", "mod2f", "cg8"][t % 4];
            let start = Instant::now();
            let mut sent = 0u64;
            let mut retries = 0u64;
            while start.elapsed().as_secs_f64() < run_secs {
                let mut args = match kernel {
                    "mod2am" => vec![
                        Arg::mat(ah.clone(), MXM_N, MXM_N),
                        Arg::mat(bh.clone(), MXM_N, MXM_N),
                    ],
                    "mod2as" => vec![Arg::vec(spmv_m.random_x(sent))],
                    "mod2f" => vec![Arg::vec(fre.clone()), Arg::vec(fim.clone())],
                    _ => vec![Arg::vec(cb.clone())],
                };
                let ticket = loop {
                    match client.try_submit(kernel, std::mem::take(&mut args)) {
                        Ok(tk) => break tk,
                        Err(SubmitError::QueueFull(back)) => {
                            retries += 1;
                            args = back;
                            std::thread::yield_now();
                        }
                        Err(e) => panic!("submit: {e}"),
                    }
                };
                ticket.wait().expect("response");
                sent += 1;
            }
            (sent, retries)
        }));
    }
    let mut total = 0u64;
    let mut retries = 0u64;
    for h in handles {
        let (s, r) = h.join().unwrap();
        total += s;
        retries += r;
    }
    println!("      {total} requests served ({retries} QueueFull retries)\n");

    // ---- report ----
    println!("[3/3] serving report");
    println!("{}", client.report());
    if let Some(pool) = arbb_rs::serve::pool::for_workers(workers) {
        let ps = arbb_rs::serve::pool::stats_of(&pool);
        println!(
            "shared pool: {} workers (persistent, process-wide), {} fork-join sweeps, {} chunk tasks",
            ps.workers, ps.sweeps, ps.chunks
        );
    }
    let cs = client.cache_stats();
    assert!(cs.hits > cs.misses, "steady-state traffic must be cache hits");
    println!(
        "capture happened {} times; {} invocations replayed cached plans.",
        cs.misses, cs.hits
    );
    println!("\nserve_euroben OK");
}

// ---- small host helpers for the FFT builder ----

fn tangle(ctx: &Context, n: usize) -> arbb_rs::coordinator::VecI64 {
    let idx: Vec<i64> =
        arbb_rs::fftlib::splitstream::tangle_indices(n).into_iter().map(|i| i as i64).collect();
    ctx.bind_i64(&idx)
}

fn twiddles(ctx: &Context, n: usize) -> (Vec1, Vec1) {
    let (re, im) = arbb_rs::fftlib::twiddle::twiddles_bitrev(n);
    (ctx.bind1(&re), ctx.bind1(&im))
}
