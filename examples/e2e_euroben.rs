//! END-TO-END driver: the full three-layer system on a real small
//! workload.
//!
//! Proves all layers compose (DESIGN.md §4, row E2E):
//!
//!  1. machine calibration (peak / bandwidth / dispatch);
//!  2. all four EuroBen kernels through the **DSL** (L3), serial and
//!     threaded, verified against the native references;
//!  3. the same four kernels through the **AOT path** — JAX/Pallas
//!     artifacts loaded and executed via the XLA **PJRT** client (L2+L1,
//!     built by `make artifacts`) — cross-checked against the DSL
//!     results;
//!  4. a paper-style summary table: MFlop/s and % of calibrated peak per
//!     kernel per path.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_euroben
//! ```

use arbb_rs::bench::{calibrate, mflops, time_best};
use arbb_rs::coordinator::{Context, CplxV};
use arbb_rs::euroben::{cg as acg, mod2am, mod2as, mod2f};
use arbb_rs::fftlib::{fft_flops, splitstream::tangle_indices};
use arbb_rs::kernels::gemm_flops;
use arbb_rs::runtime::{Input, XlaRuntime};
use arbb_rs::sparse::{banded_spd, random_csr, Csr};
use arbb_rs::util::{assert_allclose, XorShift64};

struct Row {
    kernel: &'static str,
    path: &'static str,
    mflops: f64,
    pct_peak: f64,
    checked: &'static str,
}

fn csr_to_ell(m: &Csr, k_pad: usize) -> (Vec<f64>, Vec<i32>) {
    let n = m.nrows;
    let mut vals = vec![0.0; n * k_pad];
    let mut cols = vec![0i32; n * k_pad];
    for r in 0..n {
        let (s, e) = (m.rowp[r] as usize, m.rowp[r + 1] as usize);
        for (slot, k) in (s..e).enumerate() {
            vals[r * k_pad + slot] = m.vals[k];
            cols[r * k_pad + slot] = m.indx[k] as i32;
        }
    }
    (vals, cols)
}

fn main() {
    println!("=== e2e_euroben: full-stack EuroBen run ===\n");
    println!("[1/4] calibrating machine …");
    let cal = calibrate();
    println!("      {}\n", cal.summary());
    let peak = cal.peak_flops;
    let mut rows: Vec<Row> = Vec::new();

    // ---------------- mod2am ----------------
    println!("[2/4] DSL path (L3 coordinator) …");
    let n = 256;
    let mut rng = XorShift64::new(1);
    let ah: Vec<f64> = (0..n * n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let bh: Vec<f64> = (0..n * n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let want_mxm = mod2am::reference(&ah, &bh, n);
    let ctx = Context::serial();
    let (a, b) = (ctx.bind2(&ah, n, n), ctx.bind2(&bh, n, n));
    let got = mod2am::arbb_mxm2b(&a, &b, 8).to_vec();
    assert_allclose(&got, &want_mxm, 1e-9, 1e-10, "e2e mxm dsl");
    let t = time_best(|| drop(mod2am::arbb_mxm2b(&a, &b, 8).to_vec()), 0.3, 2);
    let mf = mflops(gemm_flops(n, n, n), t);
    rows.push(Row {
        kernel: "mod2am n=256",
        path: "DSL arbb_mxm2b",
        mflops: mf,
        pct_peak: 100.0 * mf * 1e6 / peak,
        checked: "vs blocked dgemm",
    });

    // ---------------- mod2as ----------------
    let sn = 512;
    let sm = random_csr(sn, 100.0 * 16.0 / sn as f64, 11); // ~16 nnz/row
    let x = sm.random_x(3);
    let want_spmv = sm.spmv_alloc(&x);
    let ac = mod2as::bind_csr(&ctx, &sm);
    let xv = ctx.bind1(&x);
    let got = mod2as::arbb_spmv2(&ctx, &ac, &xv).to_vec();
    assert_allclose(&got, &want_spmv, 1e-11, 1e-12, "e2e spmv dsl");
    let t = time_best(|| drop(mod2as::arbb_spmv2(&ctx, &ac, &xv).to_vec()), 0.2, 3);
    let mf = mflops(2.0 * sm.nnz() as f64, t);
    rows.push(Row {
        kernel: "mod2as n=512",
        path: "DSL arbb_spmv2",
        mflops: mf,
        pct_peak: 100.0 * mf * 1e6 / peak,
        checked: "vs serial CSR",
    });

    // ---------------- mod2f ----------------
    let fn_ = 1024;
    let re: Vec<f64> = (0..fn_).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let im: Vec<f64> = (0..fn_).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let (wre, wim) = arbb_rs::kernels::fft_planned(&re, &im);
    let plan = mod2f::plan(&ctx, fn_);
    let data = CplxV { re: ctx.bind1(&re), im: ctx.bind1(&im) };
    let out = mod2f::arbb_fft(&plan, &data);
    assert_allclose(&out.re.to_vec(), &wre, 1e-8, 1e-8, "e2e fft dsl");
    let t = time_best(
        || {
            let o = mod2f::arbb_fft(&plan, &data);
            o.re.eval();
        },
        0.2,
        3,
    );
    let mf = mflops(fft_flops(fn_), t);
    rows.push(Row {
        kernel: "mod2f n=1024",
        path: "DSL split-stream",
        mflops: mf,
        pct_peak: 100.0 * mf * 1e6 / peak,
        checked: "vs planned FFT",
    });

    // ---------------- cg ----------------
    let cn = 256;
    let cbw = 7; // fits the artifact pad k=16
    let cm = banded_spd(cn, cbw, 5);
    let cb: Vec<f64> = (0..cn).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let native = arbb_rs::solvers::cg_serial(&cm, &cb, 1e-16, 4 * cn);
    let acm = mod2as::bind_csr(&ctx, &cm);
    let dsl = acg::arbb_cg(&ctx, &acm, &cb, 1e-16, 4 * cn, acg::SpmvVariant::V2);
    assert!(dsl.converged);
    assert_allclose(&dsl.x, &native.x, 1e-8, 1e-10, "e2e cg dsl");
    let t = time_best(
        || drop(acg::arbb_cg(&ctx, &acm, &cb, 1e-16, 4 * cn, acg::SpmvVariant::V2)),
        0.3,
        2,
    );
    let cg_flops = (dsl.iterations as f64) * (2.0 * cm.nnz() as f64 + 10.0 * cn as f64);
    let mf = mflops(cg_flops, t);
    rows.push(Row {
        kernel: "cg n=256 bw=7",
        path: "DSL CG+spmv2",
        mflops: mf,
        pct_peak: 100.0 * mf * 1e6 / peak,
        checked: "vs serial CG",
    });
    println!("      4 kernels verified on the DSL path\n");

    // ---------------- AOT / PJRT path ----------------
    println!("[3/4] AOT path (JAX/Pallas → HLO → PJRT) …");
    match XlaRuntime::open_default() {
        Err(e) => {
            println!("      !! artifacts unavailable ({e}) — run `make artifacts`.");
            println!("      Skipping the PJRT half of the e2e (DSL half verified).");
        }
        Ok(rt) => {
            println!("      platform: {}", rt.platform());
            // mxm
            let l = rt.load("mxm_n256").expect("mxm artifact");
            let out = l.run_f64(&[(&ah, &[n, n]), (&bh, &[n, n])]).expect("mxm run");
            assert_allclose(&out[0], &want_mxm, 1e-9, 1e-10, "e2e mxm pjrt");
            let t = time_best(|| drop(l.run_f64(&[(&ah, &[n, n]), (&bh, &[n, n])])), 0.3, 2);
            let mf = mflops(gemm_flops(n, n, n), t);
            rows.push(Row {
                kernel: "mod2am n=256",
                path: "PJRT pallas mxm",
                mflops: mf,
                pct_peak: 100.0 * mf * 1e6 / peak,
                checked: "vs DSL result",
            });

            // spmv (pad rows to k=32)
            let l = rt.load("spmv_n512_k32").expect("spmv artifact");
            let k = l.artifact.param_usize("k").unwrap();
            let (vals, cols) = csr_to_ell(&sm, k);
            let out = l
                .run(&[
                    Input::F64(&vals, &[sn, k]),
                    Input::I32(&cols, &[sn, k]),
                    Input::F64(&x, &[sn]),
                ])
                .expect("spmv run");
            assert_allclose(&out[0], &want_spmv, 1e-11, 1e-12, "e2e spmv pjrt");
            let t = time_best(
                || {
                    drop(l.run(&[
                        Input::F64(&vals, &[sn, k]),
                        Input::I32(&cols, &[sn, k]),
                        Input::F64(&x, &[sn]),
                    ]))
                },
                0.2,
                3,
            );
            let mf = mflops(2.0 * sm.nnz() as f64, t);
            rows.push(Row {
                kernel: "mod2as n=512",
                path: "PJRT pallas spmv",
                mflops: mf,
                pct_peak: 100.0 * mf * 1e6 / peak,
                checked: "vs DSL result",
            });

            // fft
            let l = rt.load("fft_n1024").expect("fft artifact");
            let idx = tangle_indices(fn_);
            let tre: Vec<f64> = idx.iter().map(|&i| re[i]).collect();
            let tim: Vec<f64> = idx.iter().map(|&i| im[i]).collect();
            let out = l.run_f64(&[(&tre, &[fn_]), (&tim, &[fn_])]).expect("fft run");
            assert_allclose(&out[0], &wre, 1e-8, 1e-8, "e2e fft pjrt");
            assert_allclose(&out[1], &wim, 1e-8, 1e-8, "e2e fft pjrt im");
            let t = time_best(|| drop(l.run_f64(&[(&tre, &[fn_]), (&tim, &[fn_])])), 0.2, 3);
            let mf = mflops(fft_flops(fn_), t);
            rows.push(Row {
                kernel: "mod2f n=1024",
                path: "PJRT pallas fft",
                mflops: mf,
                pct_peak: 100.0 * mf * 1e6 / peak,
                checked: "vs DSL result",
            });

            // cg (20 fixed iterations)
            let l = rt.load("cg_n256_k16_i20").expect("cg artifact");
            let k = l.artifact.param_usize("k").unwrap();
            let (vals, cols) = csr_to_ell(&cm, k);
            let out = l
                .run(&[
                    Input::F64(&vals, &[cn, k]),
                    Input::I32(&cols, &[cn, k]),
                    Input::F64(&cb, &[cn]),
                ])
                .expect("cg run");
            let r2 = out[1][0];
            assert!(r2 < 1e-10, "pjrt cg residual {r2}");
            let resid = arbb_rs::solvers::residual_norm(&cm, &out[0], &cb);
            assert!(resid < 1e-5, "pjrt cg |Ax-b| {resid}");
            let t = time_best(
                || {
                    drop(l.run(&[
                        Input::F64(&vals, &[cn, k]),
                        Input::I32(&cols, &[cn, k]),
                        Input::F64(&cb, &[cn]),
                    ]))
                },
                0.3,
                2,
            );
            let flops20 = 20.0 * (2.0 * cm.nnz() as f64 + 10.0 * cn as f64);
            let mf = mflops(flops20, t);
            rows.push(Row {
                kernel: "cg n=256 (20it)",
                path: "PJRT jax cg",
                mflops: mf,
                pct_peak: 100.0 * mf * 1e6 / peak,
                checked: "residual<1e-10",
            });
            println!("      4 artifacts verified on the PJRT path\n");
        }
    }

    // ---------------- summary ----------------
    println!("[4/4] summary (calibrated peak = {:.2} GFlop/s)\n", peak * 1e-9);
    println!(
        "  {:<16} {:<18} {:>12} {:>8}  {}",
        "kernel", "path", "MFlop/s", "%peak", "verification"
    );
    println!("  {}", "-".repeat(72));
    for r in &rows {
        println!(
            "  {:<16} {:<18} {:>12.1} {:>7.2}%  {}",
            r.kernel, r.path, r.mflops, r.pct_peak, r.checked
        );
    }
    println!("\ne2e_euroben OK — record these rows in EXPERIMENTS.md (E2E)");
}
